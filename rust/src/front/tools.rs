//! The [`SpiNNTools`] façade: the full Figure-8 execution flow,
//! including the §6.5 "graph changed" branch: a mutation between runs
//! triggers [`SpiNNTools::run_ticks`]'s *reconcile* path, which re-maps
//! incrementally against the persistent pipeline state (DESIGN.md §7)
//! and reloads only what actually changed — and the §6.3.5 failure
//! branch grown into a *run supervisor* (DESIGN.md §8): with
//! [`SupervisorConfig`] set, core states are polled on a cadence during
//! the run, failures are classified (RTE / watchdog / unreachable chip /
//! packets lost to a dead link), and [`HealPolicy::Remap`] re-discovers
//! the degraded machine, re-maps incrementally around the dead
//! resources, reloads the displaced vertices and restarts.

use std::collections::{BTreeMap, BTreeSet};
use std::rc::Rc;
use std::time::Instant;

use crate::apps::AppRegistry;
use crate::graph::{
    AppVertexId, ApplicationGraph, ApplicationVertexImpl, DataGenContext, MachineGraph,
    MachineVertexImpl, Slice, VertexId,
};
use crate::machine::{ChipCoord, CoreLocation, Machine};
use crate::mapping::database::{MappingDatabase, NotificationProtocol};
use crate::mapping::{map_graph_incremental, GraphMapping, Mapping, PipelineState, Placements};
use crate::runtime::Runtime;
use crate::simulator::{scamp, ChaosPlan, CoreState, SimMachine};
use crate::util::fnv1a_64;

use super::buffer::{plan_run_cycles, RunCyclePlan};
use super::bus::{EventBus, Metrics, RunEvent};
use super::checkpoint::{CheckpointConfig, Checkpointer, MemoryCheckpointer, RunSnapshot};
use super::config::{ExtractionMethod, HealPolicy, LoadMethod, SupervisorConfig, ToolsConfig};
use super::extraction::{DataPlaneOptions, FastPath};
use super::provenance::{HealReport, ProvenanceReport, RemapReport};

/// Everything that exists once a graph has been mapped and loaded.
struct RunState {
    sim: SimMachine,
    run_graph: MachineGraph,
    graph_mapping: Option<GraphMapping>,
    mapping: Mapping,
    plan: RunCyclePlan,
    fast_path: Option<FastPath>,
    /// Why the bulk data plane could not be installed, when it was
    /// wanted but unavailable — surfaced through the provenance report
    /// rather than silently falling back to SCAMP.
    data_plane_error: Option<String>,
    /// Host-side store of extracted recordings: (vertex, channel) -> data.
    recordings: BTreeMap<(VertexId, u32), Vec<u8>>,
    labels: Vec<(String, CoreLocation)>,
    ticks_done: u64,
    database: MappingDatabase,
    /// Per-vertex, per-region (length, FNV digest) of the bytes loaded
    /// into SDRAM — how reconcile decides which regions to re-transfer.
    region_digests: BTreeMap<VertexId, BTreeMap<u32, (u32, u64)>>,
    /// What the most recent mapping pass re-ran vs. reused.
    last_remap: Option<RemapReport>,
    /// Chaos events not yet scheduled into the simulator (drained as
    /// their ticks come into a run window; never re-fired).
    chaos: Option<ChaosPlan>,
    /// Cores quarantined by earlier heals: permanently excluded from
    /// re-discovery even after unloading reset their visible state.
    excluded_cores: BTreeSet<CoreLocation>,
    /// Dead-link packet losses already attributed to a finding.
    link_loss_seen: u64,
    /// One entry per self-healing pass of this run state.
    heal_reports: Vec<HealReport>,
}

/// What the supervisor found wrong during a poll.
enum FaultFinding {
    /// A core in `RunTimeError` (watchdog = false) or `Watchdog`
    /// (watchdog = true), with its IOBUF text read back.
    CoreFailure {
        loc: CoreLocation,
        label: String,
        watchdog: bool,
        iobuf: String,
    },
    /// A whole chip stopped answering: every vertex on it vanished from
    /// the core-state poll.
    UnreachableChip { chip: ChipCoord, labels: Vec<String> },
    /// Packets died on a link that was alive when routes were installed.
    LinkLoss { packets: u64 },
}

impl FaultFinding {
    fn describe(&self) -> String {
        match self {
            FaultFinding::CoreFailure { loc, label, watchdog, iobuf } => {
                let kind = if *watchdog { "watchdog" } else { "RTE" };
                let iobuf = iobuf.trim();
                if iobuf.is_empty() {
                    format!("{kind} on core {loc} ({label})")
                } else {
                    format!("{kind} on core {loc} ({label}); iobuf: {iobuf}")
                }
            }
            FaultFinding::UnreachableChip { chip, labels } => {
                format!("chip {chip:?} unreachable (vertices {labels:?})")
            }
            FaultFinding::LinkLoss { packets } => {
                format!("{packets} packets lost on a dead link")
            }
        }
    }
}

/// How one pass of the watched run loop ended.
enum RunOutcome {
    Completed,
    Faulted(Vec<FaultFinding>),
}

/// What [`SpiNNTools::remap_and_reload`] did, for heal reporting.
struct ReloadSummary {
    vertices_moved: usize,
    tables_rewritten: usize,
    map_elapsed_us: u64,
    stages_cached: usize,
    stages_rerun: usize,
}

/// A tenant session's share of one physical machine (the multi-tenant
/// [`super::MachineService`]). The service owns the single live
/// [`SimMachine`] and *lends* it to one session at a time; between
/// quanta the session's run state holds a chipless
/// [`SimMachine::hollow`] placeholder. While on loan the sim's scope is
/// set to this session's partition, so every host-side sweep (core
/// polls, signals, rediscovery, router provenance) is confined to it.
struct SharedSession {
    /// Chips of this tenant's partition — the sim scope while on loan.
    scope: BTreeSet<ChipCoord>,
    /// Chips outside the partition (other tenants' and retired boards),
    /// quarantined from placement and routing on every mapping pass.
    forbidden: BTreeSet<ChipCoord>,
    /// The lent machine, parked here when no run state exists yet to
    /// hold it (first run, or a resume after eviction).
    lent: Option<SimMachine>,
    /// Whether the service's machine currently lives in this session
    /// (in `lent` or as the run state's sim).
    holding: bool,
}

/// The SpiNNTools engine (Figure 8): setup → graphs → run → results.
pub struct SpiNNTools {
    config: ToolsConfig,
    machine_graph: MachineGraph,
    app_graph: ApplicationGraph,
    runtime: Option<Rc<Runtime>>,
    registry: AppRegistry,
    state: Option<RunState>,
    /// Persistent mapping-pipeline state (stage cache + prior outputs),
    /// the engine of incremental re-mapping. Cleared by [`Self::reset`].
    pipeline: PipelineState,
    /// Graph revisions `(machine, application)` at the last map; `None`
    /// before the first run and after `reset`.
    mapped_revisions: Option<(u64, u64)>,
    /// Why the last reconcile fell back to a full re-map, if it did
    /// (surfaced as a provenance anomaly).
    remap_note: Option<String>,
    /// Chaos injected before the run state exists; moved into the run
    /// state by the run driver.
    pending_chaos: Option<ChaosPlan>,
    /// Snapshot storage (DESIGN.md §9). Lazily created (in-memory) by
    /// the run driver when [`ToolsConfig::checkpoint`] is set and no
    /// store was installed via [`Self::set_checkpointer`].
    checkpointer: Option<Box<dyn Checkpointer>>,
    /// What the most recent reconcile threw away, when it had no
    /// snapshot to restore from (surfaced as a provenance anomaly).
    discard_note: Option<String>,
    /// `Some` when this session is a tenant of a shared machine (the
    /// multi-tenant service): partition scope, forbidden chips, and the
    /// loan slot for the service's machine.
    shared: Option<SharedSession>,
    /// The unified run-event bus (DESIGN.md §13): every run/heal/chaos/
    /// checkpoint/metrics event this session produces is published here.
    /// Observation-only by contract — with no sinks attached, emission
    /// is a counter bump. Survives [`Self::reset`] so observers outlive
    /// individual runs.
    bus: EventBus,
    pub notifications: NotificationProtocol,
}

impl SpiNNTools {
    /// Setup (§6.1). Opens the PJRT runtime if the config names an
    /// artifact directory.
    pub fn new(config: ToolsConfig) -> anyhow::Result<Self> {
        let runtime = match &config.artifacts_dir {
            Some(dir) => Some(Rc::new(Runtime::open(dir)?)),
            None => None,
        };
        let registry = AppRegistry::standard(runtime.clone());
        Ok(Self {
            config,
            machine_graph: MachineGraph::new(),
            app_graph: ApplicationGraph::new(),
            runtime,
            registry,
            state: None,
            pipeline: PipelineState::new(),
            mapped_revisions: None,
            remap_note: None,
            pending_chaos: None,
            checkpointer: None,
            discard_note: None,
            shared: None,
            bus: EventBus::new(),
            notifications: NotificationProtocol::default(),
        })
    }

    /// The session's run-event bus: attach [`super::bus::Sink`]s (works
    /// mid-run) to watch the run live.
    pub fn bus(&self) -> &EventBus {
        &self.bus
    }

    /// Replace the session's bus with a shared one (the multi-tenant
    /// service points every tenant at the service-wide bus).
    pub fn set_bus(&mut self, bus: EventBus) {
        self.bus = bus;
    }

    /// Install a snapshot store (e.g. a
    /// [`super::checkpoint::FileCheckpointer`] for restart survival).
    /// Without one, enabling [`ToolsConfig::checkpoint`] uses an
    /// in-memory store created at the first run.
    pub fn set_checkpointer(&mut self, store: Box<dyn Checkpointer>) {
        self.checkpointer = Some(store);
    }

    /// The installed snapshot store, if any.
    pub fn checkpointer(&self) -> Option<&dyn Checkpointer> {
        self.checkpointer.as_deref()
    }

    /// Inject a chaos plan: its faults strike at their ticks during the
    /// next (or current) run. Used by the chaos test suite and the E14
    /// bench; a production front end would never call this — real
    /// machines bring their own chaos.
    pub fn inject_chaos(&mut self, plan: ChaosPlan) {
        match &mut self.state {
            Some(state) => state.chaos = Some(plan),
            None => self.pending_chaos = Some(plan),
        }
    }

    /// The self-healing passes of the current run state, in order.
    pub fn heal_reports(&self) -> &[HealReport] {
        self.state.as_ref().map(|s| s.heal_reports.as_slice()).unwrap_or(&[])
    }

    // -- shared (multi-tenant) sessions (DESIGN.md §11) ----------------------

    /// Turn this session into a tenant of a shared machine: placement
    /// and routing are confined to `scope`, the `forbidden` chips
    /// (everyone else's, plus retired boards) are quarantined on every
    /// mapping pass, multicast keys are allocated inside `key_space =
    /// [base, limit)`, and the bulk data plane binds its host UDP ports
    /// from `fast_port`. Called by [`super::MachineService`] at
    /// admission, before the first loan.
    pub fn make_shared(
        &mut self,
        scope: BTreeSet<ChipCoord>,
        forbidden: BTreeSet<ChipCoord>,
        key_space: (u64, u64),
        fast_port: u16,
    ) -> anyhow::Result<()> {
        self.ensure_not_running("enter a shared session")?;
        anyhow::ensure!(key_space.0 < key_space.1, "empty tenant key window");
        anyhow::ensure!(
            key_space.1 <= super::extraction::STREAM_KEY_BASE as u64,
            "tenant key window {:#x}..{:#x} collides with the data-plane key ranges",
            key_space.0,
            key_space.1
        );
        self.config.mapping.key_space = key_space;
        self.config.fast_port = fast_port;
        self.shared = Some(SharedSession {
            scope,
            forbidden,
            lent: None,
            holding: false,
        });
        Ok(())
    }

    /// Move a shared session to a new partition (re-admission after an
    /// eviction). The key window is untouched on purpose: a snapshot
    /// being resumed carries key allocations from the old partition,
    /// and they stay valid precisely because the window follows the
    /// tenant, not the boards.
    pub fn set_partition(
        &mut self,
        scope: BTreeSet<ChipCoord>,
        forbidden: BTreeSet<ChipCoord>,
    ) -> anyhow::Result<()> {
        let sh = self
            .shared
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("set_partition on a non-shared session"))?;
        anyhow::ensure!(
            !sh.holding,
            "cannot move the partition while the machine is on loan"
        );
        sh.scope = scope;
        sh.forbidden = forbidden;
        Ok(())
    }

    /// Accept the service's machine on loan for one run quantum. The
    /// sim's sweep scope becomes this tenant's partition; the machine
    /// lands in the run state if one exists (replacing the hollow
    /// placeholder), else it is parked for the next
    /// [`Self::run_ticks`] / [`Self::resume_from`].
    pub fn lend_sim(&mut self, mut sim: SimMachine) -> anyhow::Result<()> {
        let sh = self
            .shared
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("lend_sim on a non-shared session"))?;
        anyhow::ensure!(!sh.holding, "machine already on loan to this session");
        sim.set_scope(Some(sh.scope.clone()));
        match self.state.as_mut() {
            Some(state) => state.sim = sim,
            None => sh.lent = Some(sim),
        }
        sh.holding = true;
        Ok(())
    }

    /// Return the machine to the service after a quantum, leaving a
    /// hollow placeholder behind. The sweep scope is lifted on the way
    /// out; the run state (recordings included) survives and stays
    /// readable between loans.
    pub fn reclaim_sim(&mut self) -> anyhow::Result<SimMachine> {
        let sh = self
            .shared
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("reclaim_sim on a non-shared session"))?;
        anyhow::ensure!(sh.holding, "machine is not on loan to this session");
        let mut sim = match sh.lent.take() {
            Some(sim) => sim,
            None => {
                let state = self.state.as_mut().ok_or_else(|| {
                    anyhow::anyhow!("shared session lost the machine it was holding")
                })?;
                std::mem::replace(&mut state.sim, SimMachine::hollow())
            }
        };
        sim.set_scope(None);
        sh.holding = false;
        Ok(sim)
    }

    /// Whether this session is a tenant of a shared machine.
    pub fn is_shared(&self) -> bool {
        self.shared.is_some()
    }

    /// If a shared session's on-loan machine is attached to the run
    /// state, detach it (scope intact — it is still on loan) so tearing
    /// the state down cannot drop the service's only machine.
    fn park_lent_sim(&mut self) {
        if let (Some(sh), Some(state)) = (self.shared.as_mut(), self.state.as_mut()) {
            if sh.holding && sh.lent.is_none() {
                sh.lent = Some(std::mem::replace(&mut state.sim, SimMachine::hollow()));
            }
        }
    }

    // -- graph creation (§6.2) ---------------------------------------------
    //
    // Mutations are legal at any time. Between runs they are journalled
    // (the graphs' change journals) and the next `run_ticks` takes the
    // §6.5 "graph changed" branch: an incremental re-map + reload of
    // only what changed, after which the run restarts from tick 0.

    pub fn add_machine_vertex(
        &mut self,
        v: std::sync::Arc<dyn MachineVertexImpl>,
    ) -> anyhow::Result<VertexId> {
        Ok(self.machine_graph.add_vertex(v))
    }

    pub fn add_machine_edge(
        &mut self,
        pre: VertexId,
        post: VertexId,
        partition: &str,
    ) -> anyhow::Result<()> {
        self.machine_graph.add_edge(pre, post, partition);
        Ok(())
    }

    /// Remove a machine vertex (and every edge touching it). The id is
    /// tombstoned, never reused; a next run re-maps incrementally.
    pub fn remove_machine_vertex(&mut self, v: VertexId) -> anyhow::Result<()> {
        self.machine_graph.remove_vertex(v)
    }

    /// Declare a machine vertex's resources/data changed out-of-band:
    /// the next run re-validates its pin and re-diffs its regions.
    pub fn touch_machine_vertex(&mut self, v: VertexId) -> anyhow::Result<()> {
        self.machine_graph.touch_vertex(v)
    }

    pub fn add_application_vertex(
        &mut self,
        v: std::sync::Arc<dyn ApplicationVertexImpl>,
    ) -> anyhow::Result<AppVertexId> {
        Ok(self.app_graph.add_vertex(v))
    }

    pub fn add_application_edge(
        &mut self,
        pre: AppVertexId,
        post: AppVertexId,
        partition: &str,
        payload: Option<std::sync::Arc<dyn std::any::Any + Send + Sync>>,
    ) -> anyhow::Result<()> {
        self.app_graph.add_edge(pre, post, partition, payload);
        Ok(())
    }

    /// Register a custom binary (users extend the vertex classes, §6.2).
    pub fn register_binary(
        &mut self,
        name: &str,
        factory: impl Fn() -> Box<dyn crate::simulator::CoreApp> + 'static,
    ) {
        self.registry.register(name, factory);
    }

    /// Change the mapping worker-pool width (see
    /// [`ToolsConfig::with_mapping_threads`]). A user-level option in the
    /// §6.1 sense: it never changes mapping *results*, only host
    /// wall-clock, so unlike graph edits it is allowed before any run —
    /// but not between runs, since mapping has already happened.
    pub fn set_mapping_threads(&mut self, threads: usize) -> anyhow::Result<()> {
        self.ensure_not_running("change mapping threads")?;
        self.config.mapping.options.threads = threads;
        Ok(())
    }

    /// The configured mapping worker-pool width.
    pub fn mapping_threads(&self) -> usize {
        self.config.mapping.options.threads
    }

    fn ensure_not_running(&self, what: &str) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.state.is_none(),
            "cannot {what} after a run has started; reset() first"
        );
        Ok(())
    }

    /// `(machine graph, application graph)` revisions right now.
    fn graph_revisions(&self) -> (u64, u64) {
        (self.machine_graph.revision(), self.app_graph.revision())
    }

    // -- graph execution (§6.3) --------------------------------------------

    /// Run for a simulated duration in milliseconds.
    pub fn run_ms(&mut self, ms: u64) -> anyhow::Result<()> {
        let ticks = ms * 1000 / self.config.timestep_us as u64;
        self.run_ticks(ticks.max(1))
    }

    /// Run for a number of timesteps. The first call performs machine
    /// discovery, mapping, data generation and loading. Later calls
    /// resume (§6.5) in the established Figure-9 cycle unit — unless
    /// the graph was mutated in between, in which case the run is
    /// *reconciled*: an incremental re-map (stage cache + pinned
    /// placements), a delta reload, and a restart — from the newest
    /// snapshot when [`ToolsConfig::checkpoint`] is set, from tick 0
    /// otherwise — with the work done recorded in
    /// [`Self::remap_report`].
    pub fn run_ticks(&mut self, ticks: u64) -> anyhow::Result<()> {
        self.bus.emit(RunEvent::RunStarted {
            from_tick: self.ticks_done(),
            ticks,
        });
        if self.state.is_none() {
            self.first_run(ticks)
        } else if self.mapped_revisions != Some(self.graph_revisions()) {
            self.reconcile(ticks)
        } else {
            self.resume_run(ticks)
        }?;
        self.bus.emit(RunEvent::RunCompleted { ticks_done: self.ticks_done() });
        Ok(())
    }

    /// Generate every (non-virtual) vertex's data regions against a
    /// mapping, with per-region FNV digests for the reconcile diff.
    #[allow(clippy::type_complexity)]
    fn generate_all_regions(
        run_graph: &MachineGraph,
        mapping: &Mapping,
        graph_mapping: Option<&GraphMapping>,
        app_graph: &ApplicationGraph,
        timestep_us: u32,
    ) -> anyhow::Result<(
        BTreeMap<VertexId, BTreeMap<u32, Vec<u8>>>,
        BTreeMap<VertexId, u64>,
        BTreeMap<VertexId, BTreeMap<u32, (u32, u64)>>,
    )> {
        let mut region_data: BTreeMap<VertexId, BTreeMap<u32, Vec<u8>>> = BTreeMap::new();
        let mut data_bytes: BTreeMap<VertexId, u64> = BTreeMap::new();
        let mut digests: BTreeMap<VertexId, BTreeMap<u32, (u32, u64)>> = BTreeMap::new();
        for (vid, vertex) in run_graph.vertices() {
            if vertex.virtual_link().is_some() {
                continue;
            }
            let placement = mapping
                .placement(vid)
                .ok_or_else(|| anyhow::anyhow!("vertex {} unplaced", vertex.label()))?;
            let ctx = DataGenContext {
                vertex: vid,
                placement,
                timestep_us,
                graph: run_graph,
                placements: mapping.placements.as_map(),
                keys: &mapping.keys,
                iptags: &mapping.iptags,
                reverse_iptags: &mapping.reverse_iptags,
                app_graph: graph_mapping.map(|_| app_graph),
                graph_mapping,
            };
            let regions = vertex.generate_data(&ctx);
            let total: u64 = regions.iter().map(|r| r.data.len() as u64).sum();
            data_bytes.insert(vid, total);
            digests.insert(
                vid,
                regions
                    .iter()
                    .map(|r| (r.id, (r.data.len() as u32, fnv1a_64(&r.data))))
                    .collect(),
            );
            region_data.insert(vid, regions.into_iter().map(|r| (r.id, r.data)).collect());
        }
        Ok((region_data, data_bytes, digests))
    }

    fn first_run(&mut self, ticks: u64) -> anyhow::Result<()> {
        // A first run is a from-scratch map by definition.
        self.pipeline.clear();
        self.prepare_run(ticks)?;
        let cycles = self
            .state
            .as_ref()
            .map(|s| s.plan.cycles.clone())
            .unwrap_or_default();
        self.drive_run(cycles, ticks)
    }

    /// Everything a first run does *before* driving ticks: discovery,
    /// mapping, data generation, run-cycle planning, loading, and the
    /// start signal. Split from [`Self::first_run`] so
    /// [`Self::resume_from`] can rebuild a loaded machine and then lay
    /// a snapshot over it instead of running.
    fn prepare_run(&mut self, ticks: u64) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.machine_graph.n_vertices() == 0 || self.app_graph.n_vertices() == 0,
            "it is an error to add vertices to both the application and \
             machine graphs (§6.2)"
        );
        if self.shared.is_some() {
            return self.prepare_run_shared(ticks);
        }

        // ---- machine discovery (§6.3.1) --------------------------------
        // Boot-faulted resources (§2's blacklist) are excluded here, so
        // the rest of the flow never sees them.
        let template = self.config.machine_template();

        // Application graphs are first converted to a machine graph to
        // size the machine (§6.3.1) — the same split is then used on.
        let (run_graph, graph_mapping) = if self.app_graph.n_vertices() > 0 {
            let (g, m) = crate::mapping::splitter::split_graph(&self.app_graph, &template)?;
            (g, Some(m))
        } else {
            (self.machine_graph.clone(), None)
        };

        // Virtual chips for device vertices (§5.1/§7.2).
        let mut builder = self.config.machine_builder();
        let mut next_virtual = (template.width + 1, template.height + 1);
        for (_, vertex) in run_graph.vertices() {
            if let Some(vl) = vertex.virtual_link() {
                builder = builder.virtual_chip(next_virtual, vl.attached_to, vl.direction);
                next_virtual = (next_virtual.0 + 1, next_virtual.1 + 1);
            }
        }
        let machine = builder.build();
        anyhow::ensure!(
            run_graph.n_vertices() <= machine.n_application_cores(),
            "graph needs {} cores; machine has {}",
            run_graph.n_vertices(),
            machine.n_application_cores()
        );
        let mut sim = SimMachine::boot(machine.clone(), self.config.sim.clone());
        let res = self.prepare_tail(
            ticks,
            machine,
            run_graph,
            graph_mapping,
            &BTreeSet::new(),
            &mut sim,
        );
        self.finish_prepare(res, sim)
    }

    /// [`Self::prepare_run`] for a shared (multi-tenant) session: no
    /// machine is booted here — it arrives on loan from the
    /// [`super::MachineService`], already scoped to this tenant's
    /// partition — and every chip outside the partition rides into the
    /// mapper as forbidden, on top of whatever has actually died.
    fn prepare_run_shared(&mut self, ticks: u64) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.app_graph.n_vertices() == 0,
            "application graphs are not supported in shared (multi-tenant) sessions"
        );
        let run_graph = self.machine_graph.clone();
        anyhow::ensure!(
            run_graph.vertices().all(|(_, v)| v.virtual_link().is_none()),
            "virtual device vertices are not supported in shared sessions"
        );
        let (mut sim, forbidden) = {
            let sh = self
                .shared
                .as_mut()
                .ok_or_else(|| anyhow::anyhow!("shared prepare without a shared session"))?;
            anyhow::ensure!(
                sh.holding,
                "shared session has no machine on loan; the service must lend it first"
            );
            let sim = sh.lent.take().ok_or_else(|| {
                anyhow::anyhow!("shared session machine is held by a previous run; reset() first")
            })?;
            let mut forbidden = sh.forbidden.clone();
            forbidden.extend(sim.dead_chips());
            (sim, forbidden)
        };
        let machine = sim.machine.clone();
        // Capacity is judged against the partition, not the (shared)
        // machine: the mapper never sees the other tenants' cores.
        let in_scope_cores: usize = machine
            .chips()
            .filter(|c| sim.in_scope((c.x, c.y)))
            .map(|c| c.application_processors().count())
            .sum();
        anyhow::ensure!(
            run_graph.n_vertices() <= in_scope_cores,
            "graph needs {} cores; partition has {}",
            run_graph.n_vertices(),
            in_scope_cores
        );
        let res = self.prepare_tail(ticks, machine, run_graph, None, &forbidden, &mut sim);
        self.finish_prepare(res, sim)
    }

    /// Land the prepared machine: on success it becomes the new run
    /// state's sim (replacing the hollow placeholder
    /// [`Self::prepare_tail`] left there); on failure in a shared
    /// session it goes back into the loan slot — it is the service's
    /// only machine, and an error must not drop it.
    fn finish_prepare(&mut self, res: anyhow::Result<()>, sim: SimMachine) -> anyhow::Result<()> {
        match res {
            Ok(()) => {
                let state = self
                    .state
                    .as_mut()
                    .ok_or_else(|| anyhow::anyhow!("prepare finished without a run state"))?;
                state.sim = sim;
                self.mapped_revisions = Some(self.graph_revisions());
                Ok(())
            }
            Err(e) => {
                if let Some(sh) = self.shared.as_mut() {
                    sh.lent = Some(sim);
                }
                Err(e)
            }
        }
    }

    /// Everything after machine acquisition, shared between the booted
    /// (exclusive) and on-loan (shared) paths: mapping, data
    /// generation, run-cycle planning, loading, and the start signal.
    /// Works through `sim` by reference and leaves a hollow placeholder
    /// in the new run state — [`Self::finish_prepare`] decides where
    /// the real machine lands.
    #[allow(clippy::too_many_arguments)]
    fn prepare_tail(
        &mut self,
        ticks: u64,
        machine: Machine,
        run_graph: MachineGraph,
        graph_mapping: Option<GraphMapping>,
        forbidden: &BTreeSet<ChipCoord>,
        sim: &mut SimMachine,
    ) -> anyhow::Result<()> {
        // ---- mapping (§6.3.2), on the Figure-10 engine ------------------
        let outcome = map_graph_incremental(
            &mut self.pipeline,
            &machine,
            &run_graph,
            &self.config.mapping,
            &BTreeSet::new(),
            forbidden,
        )?;
        let mapping = outcome.mapping;
        let remap = RemapReport::from_stages(
            &outcome.stages,
            run_graph.n_vertices(),
            mapping.tables.len(),
        );

        // ---- data generation (§6.3.3) -----------------------------------
        let (mut region_data, data_bytes, region_digests) = Self::generate_all_regions(
            &run_graph,
            &mapping,
            graph_mapping.as_ref(),
            &self.app_graph,
            self.config.timestep_us,
        )?;

        // ---- Figure-9 run-cycle planning --------------------------------
        let plan = plan_run_cycles(
            &machine,
            &run_graph,
            &mapping.placements,
            &data_bytes,
            ticks,
            self.config.recording_slack_bytes,
        )?;

        // ---- loading (§6.3.4) -------------------------------------------
        for (chip, table) in &mapping.tables {
            scamp::load_routing_table(sim, *chip, table.clone())?;
        }
        for tag in mapping.iptags.values() {
            scamp::set_iptag(sim, tag.board, tag.tag, &tag.host, tag.port, tag.strip_sdp)?;
        }
        for rtag in mapping.reverse_iptags.values() {
            scamp::set_reverse_iptag(sim, rtag.board, rtag.port, rtag.destination)?;
        }

        // Bulk data plane (system cores outside the user graph) — set up
        // before app loading so region data can ride the fast data-in
        // streams. A failed install is not swallowed: the reason lands
        // in the provenance report, and loading/extraction fall back to
        // the SCAMP paths.
        let want_plane = self.config.extraction == ExtractionMethod::FastMulticast
            || self.config.loading == LoadMethod::FastMulticast;
        let (fast_path, data_plane_error) = if want_plane {
            let chips: Vec<ChipCoord> = mapping.placements.used_chips().into_iter().collect();
            let placements = mapping.placements.clone();
            let machine_for_picker = machine.clone();
            let mut extra: BTreeMap<ChipCoord, std::collections::BTreeSet<u8>> = BTreeMap::new();
            let picker = move |chip: ChipCoord| -> Option<u8> {
                let used = placements.cores_used_on(chip);
                let taken = extra.entry(chip).or_default();
                let chip_info = machine_for_picker.chip(chip)?;
                for p in chip_info.application_processors().map(|p| p.id) {
                    if !used.contains(&p) && !taken.contains(&p) {
                        taken.insert(p);
                        return Some(p);
                    }
                }
                None // fully packed: this chip falls back to the SCAMP paths
            };
            let opts = DataPlaneOptions {
                port_base: self.config.fast_port,
                extraction: self.config.extraction == ExtractionMethod::FastMulticast,
                data_in: self.config.loading == LoadMethod::FastMulticast,
                threads: self.config.data_plane_threads,
            };
            match FastPath::install(sim, &chips, picker, &opts) {
                Ok(fp) => {
                    // Start the plane's system binaries now — the user
                    // graph is not loaded yet, so only they are Ready —
                    // else the data-in cores could not serve the region
                    // load below (their on_start reads the stream config).
                    scamp::signal_start(sim)?;
                    (Some(fp), None)
                }
                Err(e) => (None, Some(e.to_string())),
            }
        } else {
            (None, None)
        };

        let mut labels = Vec::new();
        // Region loading + binary attach. Fast data-in batches every
        // region into one multi-board streamed load; chips without a
        // writer core take the batched SCAMP fallback.
        let mut fast_reqs: Vec<(ChipCoord, u32, Vec<u8>)> = Vec::new();
        for (vid, vertex) in run_graph.vertices() {
            if vertex.virtual_link().is_some() {
                continue;
            }
            let loc = mapping
                .placement(vid)
                .ok_or_else(|| anyhow::anyhow!("vertex {} unplaced at load", vertex.label()))?;
            labels.push((vertex.label(), loc));
            let app = self.registry.create(&vertex.binary_name())?;
            let mut recording_sizes = BTreeMap::new();
            if let Some(bytes) = plan.recording_bytes.get(&vid) {
                recording_sizes.insert(0u32, *bytes as u32);
            }
            let regions = region_data.remove(&vid).unwrap_or_default();
            let use_fast = self.config.loading == LoadMethod::FastMulticast
                && fast_path.as_ref().is_some_and(|fp| fp.has_writer(loc.chip()));
            if self.config.loading == LoadMethod::Scamp {
                scamp::load_app_named(
                    sim,
                    loc,
                    &vertex.binary_name(),
                    app,
                    regions,
                    recording_sizes,
                )?;
            } else {
                let mut table = BTreeMap::new();
                for (id, data) in regions {
                    let addr = scamp::alloc_sdram(sim, loc.chip(), data.len() as u32)?;
                    table.insert(id, (addr, data.len() as u32));
                    if use_fast {
                        fast_reqs.push((loc.chip(), addr, data));
                    } else if !data.is_empty() {
                        scamp::write_sdram_batched(sim, loc.chip(), addr, &data)?;
                    }
                }
                scamp::install_app(
                    sim,
                    loc,
                    &vertex.binary_name(),
                    app,
                    table,
                    recording_sizes,
                )?;
            }
        }
        if !fast_reqs.is_empty() {
            let fp = fast_path.as_ref().ok_or_else(|| {
                anyhow::anyhow!(
                    "{} fast-load request(s) queued but no data plane is installed \
                     (loading = FastMulticast without a usable plane)",
                    fast_reqs.len()
                )
            })?;
            let reqs: Vec<(ChipCoord, u32, &[u8])> = fast_reqs
                .iter()
                .map(|(chip, addr, data)| (*chip, *addr, data.as_slice()))
                .collect();
            fp.write_many(sim, &reqs)?;
        }

        // ---- database + notifications (Figure 8) ------------------------
        let database = MappingDatabase::build(&run_graph, &mapping.placements, &mapping.keys);
        self.notifications.database_ready(&database);

        // ---- running (§6.3.5) -------------------------------------------
        scamp::signal_start(sim)?;
        let state = RunState {
            // The real machine is the caller's local; finish_prepare
            // swaps it in over this placeholder once the tail succeeds.
            sim: SimMachine::hollow(),
            run_graph,
            graph_mapping,
            mapping,
            plan,
            fast_path,
            data_plane_error,
            recordings: BTreeMap::new(),
            labels,
            ticks_done: 0,
            database,
            region_digests,
            last_remap: Some(remap),
            chaos: None,
            excluded_cores: BTreeSet::new(),
            link_loss_seen: 0,
            heal_reports: Vec::new(),
        };
        self.state = Some(state);
        Ok(())
    }

    fn resume_run(&mut self, ticks: u64) -> anyhow::Result<()> {
        let state = self
            .state
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("resume_run without a run state"))?;
        // "The minimum time calculated previously is respected" (§6.5).
        let unit = state.plan.steps_per_cycle;
        let mut cycles = Vec::new();
        let mut remaining = ticks;
        while remaining > 0 {
            let c = unit.min(remaining);
            cycles.push(c);
            remaining -= c;
        }
        scamp::signal_resume(&mut state.sim)?;
        self.drive_run(cycles, ticks)
    }

    // -- the §6.5 "graph changed" branch ------------------------------------

    /// Re-map and reload after a graph mutation, then restart the run —
    /// from the newest snapshot when one exists (survivors keep their
    /// state and the pre-mutation recordings survive), from tick 0
    /// otherwise (the discarded recordings surface as a provenance
    /// anomaly). Incremental wherever the fingerprints and pins
    /// allow; any infeasibility (pinned placement conflicts, TCAM
    /// overflow with the data plane's stream entries, a new device
    /// vertex needing a virtual chip, application-graph changes) falls
    /// back to a full from-scratch re-map — semantically identical,
    /// just slower.
    fn reconcile(&mut self, ticks: u64) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.machine_graph.n_vertices() == 0 || self.app_graph.n_vertices() == 0,
            "it is an error to add vertices to both the application and \
             machine graphs (§6.2)"
        );
        self.remap_note = None;
        self.discard_note = None;
        // What the pre-mutation run had already recorded. If there is no
        // snapshot to restore it from, throwing it away must not be
        // silent (it surfaces as a provenance anomaly).
        let (rec_bytes, rec_channels) = self
            .state
            .as_ref()
            .map(|s| {
                (
                    s.recordings.values().map(Vec::len).sum::<usize>(),
                    s.recordings.len(),
                )
            })
            .unwrap_or((0, 0));
        let restore = self.newest_snapshot();
        // Application graphs re-split globally — there is no sound
        // per-vertex pinning across the splitter — so any app-graph
        // change is a full re-map.
        let app_changed = self
            .mapped_revisions
            .map(|(_, a)| a != self.app_graph.revision())
            .unwrap_or(true);
        let was_app_run = self
            .state
            .as_ref()
            .is_some_and(|s| s.graph_mapping.is_some());
        if app_changed || was_app_run {
            self.note_reconcile_discard(rec_bytes, rec_channels);
            return self.full_remap(ticks, "application graph changed");
        }
        if let Err(e) = self.reconcile_map_and_load(ticks) {
            self.note_reconcile_discard(rec_bytes, rec_channels);
            return self.full_remap(ticks, &e.to_string());
        }
        self.mapped_revisions = Some(self.graph_revisions());
        if let Some((rerun, cached)) = self
            .remap_report()
            .map(|r| (r.stages_rerun, r.stages_cached))
        {
            self.bus
                .emit(RunEvent::Reconciled { stages_rerun: rerun, stages_cached: cached });
        }
        if let Some(snap) = &restore {
            // Preserve the pre-mutation run: recordings come back from
            // the snapshot, unchanged survivors get their evolving state
            // back, and the run continues from the snapshot tick.
            // Vertices whose regions the mutation rewrote start fresh —
            // their new data must win, so they are not restored over.
            self.apply_snapshot_survivors(snap)?;
        } else {
            self.note_reconcile_discard(rec_bytes, rec_channels);
        }
        // The run itself is outside the fallback: a core hitting a
        // runtime error is a real failure, not a mapping infeasibility.
        let state = self
            .state
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("reconcile lost the run state"))?;
        let cycles = state.plan.cycles.clone();
        self.drive_run(cycles, ticks)
    }

    /// Record that a reconcile threw away the pre-mutation recordings
    /// because it had no snapshot to restore them from.
    fn note_reconcile_discard(&mut self, bytes: usize, channels: usize) {
        if bytes > 0 {
            self.discard_note = Some(format!(
                "reconcile discarded {bytes} byte(s) of recordings from {channels} \
                 channel(s); enable ToolsConfig::checkpoint to preserve them"
            ));
        }
    }

    /// Tear everything down and re-run the whole Figure-8 flow with the
    /// current graphs. `why` is surfaced as a provenance anomaly so the
    /// fallback is never silent.
    fn full_remap(&mut self, ticks: u64, why: &str) -> anyhow::Result<()> {
        self.remap_note = Some(format!("graph change forced a full re-map: {why}"));
        self.park_lent_sim();
        self.state = None;
        self.pipeline.clear();
        if let Some(store) = self.checkpointer.as_deref_mut() {
            // Stale snapshots cannot be laid over a from-scratch re-map
            // (the torn-down run is a new workload), and their high
            // ticks would suppress every capture of the restarted run.
            // Region blobs stay — identical data re-captures for free.
            store.prune(0)?;
        }
        self.first_run(ticks)
    }

    /// The incremental half of [`Self::reconcile`]: map against the
    /// persistent pipeline, unload removed vertices, reinstall only
    /// changed routing tables (with the data plane's stream entries
    /// re-appended), rewrite only regions whose bytes changed, and
    /// restart every application core from Ready.
    fn reconcile_map_and_load(&mut self, ticks: u64) -> anyhow::Result<()> {
        let machine = self
            .state
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("reconcile without a run state"))?
            .sim
            .machine
            .clone();
        self.remap_and_reload(ticks, machine, &BTreeSet::new())?;
        Ok(())
    }

    /// Incrementally re-map the current machine graph against `machine`
    /// (with `forbidden` chips quarantined) and reload the delta:
    /// vertices that left the graph are unloaded, *moved* vertices —
    /// displaced off dead resources by a heal, or re-placed after a
    /// graph change — are unloaded at their old core (when it is still
    /// reachable) and installed in full at the new one, survivors are
    /// reloaded in place with only changed region bytes re-transferred,
    /// and every application core restarts from Ready. Shared by the
    /// §6.5 reconcile path (`machine` = the live machine, no forbidden
    /// chips) and the supervisor's heal path (`machine` = the degraded
    /// re-discovered view, `forbidden` = the chips that died).
    fn remap_and_reload(
        &mut self,
        ticks: u64,
        machine: Machine,
        forbidden: &BTreeSet<ChipCoord>,
    ) -> anyhow::Result<ReloadSummary> {
        let run_graph = self.machine_graph.clone();
        // In a shared session the machine view still contains the other
        // tenants' chips (re-discovery filters its *sweep* to the scope,
        // not the clone), so the partition boundary rides in as
        // forbidden chips and capacity is judged against the partition
        // alone.
        let mut forbidden_all = forbidden.clone();
        let capacity: usize = match &self.shared {
            Some(sh) => {
                forbidden_all.extend(sh.forbidden.iter().copied());
                machine
                    .chips()
                    .filter(|c| sh.scope.contains(&(c.x, c.y)))
                    .map(|c| c.application_processors().count())
                    .sum()
            }
            None => machine.n_application_cores(),
        };
        let forbidden = &forbidden_all;
        let state = self
            .state
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("remap without a run state"))?;
        anyhow::ensure!(
            run_graph.n_vertices() <= capacity,
            "graph needs {} cores; machine has {}",
            run_graph.n_vertices(),
            capacity
        );
        let mut reserved: BTreeSet<CoreLocation> = state
            .fast_path
            .as_ref()
            .map(|fp| fp.system_cores())
            .unwrap_or_default();
        // Cores quarantined by earlier heals stay off-limits even when
        // the machine view passed in (e.g. the live machine on a plain
        // reconcile) still lists their processors.
        reserved.extend(state.excluded_cores.iter().copied());

        // ---- incremental mapping ---------------------------------------
        let map_t0 = Instant::now();
        let outcome = map_graph_incremental(
            &mut self.pipeline,
            &machine,
            &run_graph,
            &self.config.mapping,
            &reserved,
            forbidden,
        )?;
        let map_elapsed_us = map_t0.elapsed().as_micros() as u64;
        let mapping = outcome.mapping;

        // ---- unload vertices that left the graph -----------------------
        let prior_placements: Vec<(VertexId, CoreLocation)> =
            state.mapping.placements.iter().collect();
        for (vid, loc) in &prior_placements {
            if mapping.placement(*vid).is_none() {
                // Virtual (device) vertices have no simulated core, and
                // cores on dead chips are beyond unloading.
                if state.run_graph.vertex(*vid).virtual_link().is_none()
                    && scamp::core_state(&state.sim, *loc).is_ok()
                {
                    scamp::unload_app(&mut state.sim, *loc)?;
                }
                state.region_digests.remove(vid);
            }
        }

        // ---- data regeneration + Figure-9 plan -------------------------
        let (mut region_data, data_bytes, new_digests) = Self::generate_all_regions(
            &run_graph,
            &mapping,
            None,
            &self.app_graph,
            self.config.timestep_us,
        )?;
        let plan = plan_run_cycles(
            &machine,
            &run_graph,
            &mapping.placements,
            &data_bytes,
            ticks,
            self.config.recording_slack_bytes,
        )?;

        // ---- reinstall only the routing tables that changed ------------
        // `install_table` under each load invalidates the chip's route
        // cache, so stale memoised lookups cannot survive the re-map.
        // Chips that died take their tables to the grave: the pipeline
        // marks them "changed" (their table vanished) but there is no
        // router left to load.
        let mut tables_rewritten = 0usize;
        for chip in &outcome.install_chips {
            if state.sim.machine.chip(*chip).is_none() {
                continue;
            }
            let mut table = mapping.tables.get(chip).cloned().unwrap_or_default();
            if let Some(fp) = &state.fast_path {
                for e in fp.stream_entries(*chip) {
                    table.push(*e);
                }
            }
            scamp::load_routing_table(&mut state.sim, *chip, table)?;
            tables_rewritten += 1;
        }

        // ---- (re)apply tags (idempotent overwrites) --------------------
        // The tag allocator knows nothing of the data plane's system
        // tags (installed after the first map): a newly-allocated user
        // tag landing on one would silently hijack the plane's streams.
        // Collisions force the full-re-map fallback, which re-seeds the
        // plane's allocator from the user tags.
        if let Some(fp) = &state.fast_path {
            let stags = fp.system_tags();
            let sports = fp.system_reverse_ports();
            for tag in mapping.iptags.values() {
                anyhow::ensure!(
                    !stags.contains(&(tag.board, tag.tag)),
                    "user IP tag {} on board {:?} collides with a data-plane tag",
                    tag.tag,
                    tag.board
                );
            }
            for rtag in mapping.reverse_iptags.values() {
                anyhow::ensure!(
                    !sports.contains(&(rtag.board, rtag.port)),
                    "user reverse IP tag port {} on board {:?} collides with the data plane",
                    rtag.port,
                    rtag.board
                );
            }
        }
        for tag in mapping.iptags.values() {
            scamp::set_iptag(
                &mut state.sim,
                tag.board,
                tag.tag,
                &tag.host,
                tag.port,
                tag.strip_sdp,
            )?;
        }
        for rtag in mapping.reverse_iptags.values() {
            scamp::set_reverse_iptag(&mut state.sim, rtag.board, rtag.port, rtag.destination)?;
        }

        // ---- per-vertex reload: new/moved in full, survivors by diff ---
        let mut labels = Vec::new();
        let mut vertices_replaced = 0usize;
        let mut vertices_moved = 0usize;
        let mut fast_reqs: Vec<(ChipCoord, u32, Vec<u8>)> = Vec::new();
        for (vid, vertex) in run_graph.vertices() {
            if vertex.virtual_link().is_some() {
                continue;
            }
            let loc = mapping
                .placement(vid)
                .ok_or_else(|| anyhow::anyhow!("vertex {} unplaced at reload", vertex.label()))?;
            labels.push((vertex.label(), loc));
            let app = self.registry.create(&vertex.binary_name())?;
            let mut recording_sizes = BTreeMap::new();
            if let Some(bytes) = plan.recording_bytes.get(&vid) {
                recording_sizes.insert(0u32, *bytes as u32);
            }
            let regions = region_data.remove(&vid).unwrap_or_default();
            let old_loc = state.mapping.placement(vid);
            let moved = old_loc.is_some_and(|ol| ol != loc);
            if moved {
                // Displaced off a dead resource (or re-placed after a
                // graph change): clear the old core when it is still
                // reachable and loaded, then install fresh at the new
                // one. The old region bytes are unreachable or stale
                // either way, so the diff path does not apply.
                vertices_moved += 1;
                let ol = old_loc.ok_or_else(|| {
                    anyhow::anyhow!(
                        "vertex {} flagged as moved without a prior placement",
                        vertex.label()
                    )
                })?;
                if scamp::core_state(&state.sim, ol)
                    .is_ok_and(|s| s != CoreState::Idle)
                {
                    scamp::unload_app(&mut state.sim, ol)?;
                }
                state.region_digests.remove(&vid);
            }
            let is_new = old_loc.is_none() || moved;
            let use_fast = self.config.loading == LoadMethod::FastMulticast
                && state
                    .fast_path
                    .as_ref()
                    .is_some_and(|fp| fp.has_writer(loc.chip()));
            let mut write = |sim: &mut SimMachine,
                             fast_reqs: &mut Vec<(ChipCoord, u32, Vec<u8>)>,
                             addr: u32,
                             data: Vec<u8>|
             -> anyhow::Result<()> {
                if use_fast {
                    fast_reqs.push((loc.chip(), addr, data));
                } else if self.config.loading == LoadMethod::Scamp {
                    scamp::write_sdram(sim, loc.chip(), addr, &data)?;
                } else {
                    scamp::write_sdram_batched(sim, loc.chip(), addr, &data)?;
                }
                Ok(())
            };
            if is_new {
                let mut table = BTreeMap::new();
                for (id, data) in regions {
                    let addr = scamp::alloc_sdram(&mut state.sim, loc.chip(), data.len() as u32)?;
                    table.insert(id, (addr, data.len() as u32));
                    if !data.is_empty() {
                        write(&mut state.sim, &mut fast_reqs, addr, data)?;
                    }
                }
                scamp::install_app(
                    &mut state.sim,
                    loc,
                    &vertex.binary_name(),
                    app,
                    table,
                    recording_sizes,
                )?;
                vertices_replaced += 1;
            } else {
                let old_table = scamp::region_table(&state.sim, loc)?;
                let old_digests =
                    state.region_digests.get(&vid).cloned().unwrap_or_default();
                let mut table = BTreeMap::new();
                let mut rewrote = false;
                for (id, data) in regions {
                    let len = data.len() as u32;
                    let unchanged = old_digests.get(&id).copied()
                        == Some((len, fnv1a_64(&data)))
                        && old_table.get(&id).map(|(_, l)| *l) == Some(len);
                    // Same-length regions are rewritten in place; a new
                    // length takes a fresh allocation (the simulated
                    // bump allocator does not reclaim — documented).
                    let addr = match old_table.get(&id).copied() {
                        Some((addr, olen)) if olen == len => addr,
                        _ => scamp::alloc_sdram(&mut state.sim, loc.chip(), len)?,
                    };
                    table.insert(id, (addr, len));
                    if unchanged || data.is_empty() {
                        continue;
                    }
                    rewrote = true;
                    write(&mut state.sim, &mut fast_reqs, addr, data)?;
                }
                scamp::reload_app(
                    &mut state.sim,
                    loc,
                    &vertex.binary_name(),
                    app,
                    table,
                    recording_sizes,
                )?;
                if rewrote {
                    vertices_replaced += 1;
                }
            }
        }
        if !fast_reqs.is_empty() {
            let fp = state
                .fast_path
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("fast requests without a data plane"))?;
            let reqs: Vec<(ChipCoord, u32, &[u8])> = fast_reqs
                .iter()
                .map(|(chip, addr, data)| (*chip, *addr, data.as_slice()))
                .collect();
            fp.write_many(&mut state.sim, &reqs)?;
        }

        // ---- database + notifications + restart ------------------------
        let database = MappingDatabase::build(&run_graph, &mapping.placements, &mapping.keys);
        self.notifications.database_ready(&database);
        // Every reinstalled user core is Ready; the data plane's system
        // cores are untouched (they serve transfers in any state and
        // rejoin at the next run cycle).
        scamp::signal_start(&mut state.sim)?;

        state.run_graph = run_graph;
        state.mapping = mapping;
        state.plan = plan;
        state.recordings.clear();
        state.labels = labels;
        state.ticks_done = 0;
        // Re-baseline the dead-link loss counter: losses before this
        // remap are already attributed to a finding (or predate it).
        state.link_loss_seen = state.sim.total_router_stats().mc_dead_link;
        state.database = database;
        state.region_digests = new_digests;
        state.last_remap = Some(RemapReport::from_stages(
            &outcome.stages,
            vertices_replaced,
            tables_rewritten,
        ));
        Ok(ReloadSummary {
            vertices_moved,
            tables_rewritten,
            map_elapsed_us,
            stages_cached: outcome.stages.iter().filter(|s| s.cached).count(),
            stages_rerun: outcome.stages.iter().filter(|s| !s.cached).count(),
        })
    }

    /// The run driver: execute the Figure-9 cycles, supervised when
    /// [`ToolsConfig::supervision`] is set. A detected failure either
    /// aborts with the failed cores' IOBUF text attached
    /// ([`HealPolicy::Abort`]) or heals — re-discover, re-map around the
    /// dead resources, reload the displaced vertices — and restarts from
    /// tick 0 ([`HealPolicy::Remap`]), replaying the *whole* tick
    /// history (ticks completed by earlier `run_ticks` calls plus this
    /// one) on the degraded machine, so the final recordings equal an
    /// unfaulted full run on that machine.
    fn drive_run(&mut self, mut cycles: Vec<u64>, total_ticks: u64) -> anyhow::Result<()> {
        let supervision = self.config.supervision;
        let extraction = self.config.extraction;
        let ckpt = self.config.checkpoint;
        if ckpt.is_some() && self.checkpointer.is_none() {
            self.checkpointer = Some(Box::new(MemoryCheckpointer::new()));
        }
        let revisions = self.graph_revisions();
        // Ticks already completed before this call (a resumed run): a
        // heal's restart must cover them too.
        let base_ticks = self
            .state
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("run driver without a run state"))?
            .ticks_done;
        let mut heals_done = 0usize;
        let bus = self.bus.clone();
        loop {
            // Re-read each pass: a heal's re-map may advance the key
            // allocator, and later captures must carry the new cursor.
            let key_cursor = self.pipeline.key_cursor().unwrap_or(0);
            let pending = self.pending_chaos.take();
            let state = self
                .state
                .as_mut()
                .ok_or_else(|| anyhow::anyhow!("run driver without a run state"))?;
            if let Some(plan) = pending {
                state.chaos = Some(plan);
            }
            match Self::run_cycles_watched(
                state,
                &cycles,
                extraction,
                supervision.as_ref(),
                ckpt,
                self.checkpointer.as_deref_mut(),
                revisions,
                key_cursor,
                &bus,
            )? {
                RunOutcome::Completed => return self.check_completion(),
                RunOutcome::Faulted(findings) => {
                    for f in &findings {
                        bus.emit(RunEvent::Fault { description: f.describe() });
                    }
                    let sup = supervision.ok_or_else(|| {
                        anyhow::anyhow!(
                            "run driver surfaced {} fault finding(s) without supervision \
                             configured; first: {}",
                            findings.len(),
                            findings[0].describe()
                        )
                    })?;
                    match sup.policy {
                        HealPolicy::Abort => {
                            let mut msg = String::from("run aborted by supervisor:");
                            for f in &findings {
                                msg.push_str("\n  - ");
                                msg.push_str(&f.describe());
                            }
                            anyhow::bail!("{msg}");
                        }
                        HealPolicy::Remap => {
                            anyhow::ensure!(
                                heals_done < sup.max_heals,
                                "machine is failing faster than it can heal \
                                 ({} heal(s) exhausted); latest: {}",
                                sup.max_heals,
                                findings[0].describe()
                            );
                            heals_done += 1;
                            self.heal(&findings, base_ticks + total_ticks)?;
                            cycles = self
                                .state
                                .as_ref()
                                .ok_or_else(|| {
                                    anyhow::anyhow!(
                                        "run state lost while healing around: {}",
                                        findings[0].describe()
                                    )
                                })?
                                .plan
                                .cycles
                                .clone();
                        }
                    }
                }
            }
        }
    }

    /// The Figure-9 loop: run a cycle, drain recordings, flush, resume —
    /// supervised. Under supervision each cycle runs in
    /// `poll_interval_ticks` chunks; after every chunk the core states
    /// are polled and classified. Chaos events whose tick falls inside a
    /// chunk are scheduled into the simulator as that chunk starts (and
    /// drained from the plan: a healed run's restart does not re-fire
    /// them). A chaos tick landing exactly *on* a chunk boundary belongs
    /// to the next chunk — "after tick `t` completes" means after the
    /// boundary, so the boundary poll still observes a pre-fault
    /// machine. That is also what makes checkpoint captures sound:
    /// snapshots are taken only after a clean poll, so every stored
    /// snapshot predates the effects of any fault found later.
    ///
    /// With [`CheckpointConfig`] set, a [`RunSnapshot`] is captured at
    /// the first clean chunk boundary at or past each
    /// `interval_ticks`-sized stride (recordings are drained to the
    /// host first, so core-side buffers are empty in the capture).
    #[allow(clippy::too_many_arguments)]
    fn run_cycles_watched(
        state: &mut RunState,
        cycles: &[u64],
        extraction: ExtractionMethod,
        supervision: Option<&SupervisorConfig>,
        ckpt: Option<CheckpointConfig>,
        mut store: Option<&mut dyn Checkpointer>,
        revisions: (u64, u64),
        key_cursor: u64,
        bus: &EventBus,
    ) -> anyhow::Result<RunOutcome> {
        let timestep_ns = state.sim.config.timestep_us as u64 * 1000;
        // Metrics sampling window (chunk boundaries). Router totals are
        // read only when someone is listening, so an unwatched run does
        // no extra work. The baseline is `None` while unwatched: a sink
        // attaching mid-run must not see the machine's cumulative
        // packet count reported as a single window's delta.
        let mut window_wall = Instant::now();
        let mut window_packets: Option<u64> = if bus.has_sinks() {
            let r = state.sim.total_router_stats();
            Some(r.mc_routed + r.mc_default_routed)
        } else {
            None
        };
        for (i, cycle) in cycles.iter().enumerate() {
            if i > 0 {
                scamp::signal_resume(&mut state.sim)?;
            }
            // Supervised runs chunk at the poll cadence (captures ride
            // the poll boundaries); unsupervised checkpointing runs
            // chunk at the capture cadence.
            let chunk = supervision
                .map(|s| s.poll_interval_ticks.max(1))
                .or(ckpt.map(|c| c.interval_ticks))
                .unwrap_or(*cycle)
                .max(1);
            let mut done_in_cycle = 0u64;
            while done_in_cycle < *cycle {
                let step = chunk.min(*cycle - done_in_cycle);
                if done_in_cycle > 0 {
                    scamp::signal_resume(&mut state.sim)?;
                }
                // Chaos due within this chunk's tick window strikes
                // mid-tick-interval, after its tick's timer events. The
                // window is `(abs_done, abs_done + step)` — an event at
                // exactly `abs_done + step` fires as the *next* chunk
                // starts (same point in tick time, observed one poll
                // later).
                let abs_done = state.ticks_done + done_in_cycle;
                if let Some(plan) = &mut state.chaos {
                    let mut rest = Vec::with_capacity(plan.events.len());
                    for ev in plan.events.drain(..) {
                        if ev.at_tick < abs_done + step {
                            bus.emit(RunEvent::ChaosInjected {
                                at_tick: ev.at_tick,
                                fault: ev.fault.to_string(),
                            });
                            let delta = ev.at_tick.saturating_sub(abs_done);
                            state
                                .sim
                                .schedule_fault(delta * timestep_ns + timestep_ns / 2, ev.fault);
                        } else {
                            rest.push(ev);
                        }
                    }
                    plan.events = rest;
                }
                state.sim.start_run_cycle(step);
                state.sim.run_until_idle()?;
                done_in_cycle += step;
                if supervision.is_some() {
                    let findings = Self::supervisor_poll(state)?;
                    if !findings.is_empty() {
                        return Ok(RunOutcome::Faulted(findings));
                    }
                }
                if let (Some(cfg), Some(store)) = (ckpt, store.as_deref_mut()) {
                    let abs = state.ticks_done + done_in_cycle;
                    let last = store.snapshot_ticks().last().copied().unwrap_or(0);
                    if abs > last && abs - last >= cfg.interval_ticks {
                        Self::capture_snapshot(
                            state, abs, revisions, key_cursor, extraction, store,
                        )?;
                        store.prune(cfg.keep)?;
                        bus.emit(RunEvent::CheckpointCaptured { tick: abs });
                    }
                }
                if bus.has_sinks() {
                    let r = state.sim.total_router_stats();
                    let packets_now = r.mc_routed + r.mc_default_routed;
                    // First watched boundary since attach: no baseline,
                    // so report an empty window rather than a spike of
                    // the whole run's cumulative count.
                    let packets =
                        window_packets.map_or(0, |prev| packets_now.saturating_sub(prev));
                    let wall = window_wall.elapsed().as_secs_f64().max(1e-9);
                    let wire = state.sim.wire_stats();
                    bus.emit(RunEvent::Metrics(Metrics {
                        tick: state.ticks_done + done_in_cycle,
                        sim_ns: state.sim.now_ns(),
                        ticks_per_sec: step as f64 / wall,
                        packets_per_sec: packets as f64 / wall,
                        packets,
                        wire_retries: wire.scp_retries + wire.bulk_retry_waits,
                        tenant: None,
                        quantum_latency_us: None,
                    }));
                    window_packets = Some(packets_now);
                } else {
                    window_packets = None;
                }
                window_wall = Instant::now();
            }
            state.ticks_done += cycle;
            Self::extract_recordings(state, extraction)?;
        }
        Ok(RunOutcome::Completed)
    }

    // -- checkpoint/restore (DESIGN.md §9, E15) ------------------------------

    /// Capture a [`RunSnapshot`] of the run at `tick` into `store`.
    /// Recordings are drained to the host first (so the per-core
    /// capture carries empty buffers that always fit a later, smaller
    /// replay plan), then every placed vertex's core is captured and
    /// any region blob the store has not seen is read back from SDRAM —
    /// the incremental half: regions unchanged since the last capture
    /// cost nothing.
    fn capture_snapshot(
        state: &mut RunState,
        tick: u64,
        revisions: (u64, u64),
        key_cursor: u64,
        extraction: ExtractionMethod,
        store: &mut dyn Checkpointer,
    ) -> anyhow::Result<RunSnapshot> {
        Self::extract_recordings(state, extraction)?;
        let mut placements = Vec::new();
        for (vid, vertex) in state.run_graph.vertices() {
            if vertex.virtual_link().is_some() {
                continue;
            }
            let loc = state.mapping.placement(vid).ok_or_else(|| {
                anyhow::anyhow!("vertex {} unplaced at snapshot capture", vertex.label())
            })?;
            placements.push((vid, loc));
        }
        let mut cores = BTreeMap::new();
        let mut regions = BTreeMap::new();
        for (vid, loc) in &placements {
            cores.insert(*vid, scamp::capture_core(&mut state.sim, *loc)?);
            if let Some(digests) = state.region_digests.get(vid) {
                let table = scamp::region_table(&state.sim, *loc)?;
                for (id, (len, digest)) in digests {
                    if *len == 0 || store.has_blob(*digest) {
                        continue;
                    }
                    let (addr, alen) = table.get(id).copied().ok_or_else(|| {
                        anyhow::anyhow!("region {id} of vertex {vid:?} missing at capture")
                    })?;
                    anyhow::ensure!(
                        alen == *len,
                        "region {id} of vertex {vid:?}: digest says {len} bytes, \
                         table says {alen}"
                    );
                    let bytes =
                        scamp::read_sdram(&mut state.sim, loc.chip(), addr, *len as usize)?;
                    store.put_blob(*digest, &bytes)?;
                }
                regions.insert(*vid, digests.clone());
            }
        }
        let snap = RunSnapshot {
            tick,
            steps_per_cycle: state.plan.steps_per_cycle,
            revisions,
            cores,
            regions,
            host_recordings: state.recordings.clone(),
            pending_chaos: state
                .chaos
                .as_ref()
                .map(|p| p.events.clone())
                .unwrap_or_default(),
            placements,
            keys: state.mapping.keys.clone(),
            key_cursor,
        };
        store.put_snapshot(&snap)?;
        Ok(snap)
    }

    /// The newest stored snapshot, decoded — `None` when checkpointing
    /// is off, no store is installed, or nothing has been captured yet.
    fn newest_snapshot(&self) -> Option<RunSnapshot> {
        if self.config.checkpoint.is_none() {
            return None;
        }
        let store = self.checkpointer.as_ref()?;
        let tick = store.snapshot_ticks().last().copied()?;
        store.get_snapshot(tick).ok()
    }

    /// Restore a snapshot onto the *current* run state (which must be
    /// freshly mapped and started — every user core Ready→Running with
    /// its static regions loaded). Vertices in the snapshot that are no
    /// longer placed (removed by a reconcile) are skipped; vertices not
    /// in the snapshot (added by a reconcile) keep their fresh state
    /// and start counting ticks from zero. Region bytes are rewritten
    /// only where the loaded digest differs from the captured one; app
    /// state, recording cursors, provenance and IOBUF are restored on
    /// every captured core, and the host recording store is reset to
    /// the captured prefix.
    fn apply_snapshot(&mut self, snap: &RunSnapshot) -> anyhow::Result<()> {
        self.apply_snapshot_inner(snap, false)
    }

    /// The reconcile flavour of [`Self::apply_snapshot`]: restore only
    /// vertices whose region data the mutation did *not* rewrite. The
    /// mutated vertices keep their freshly loaded data and start their
    /// local tick stream from zero; the host recording store is still
    /// reset to the captured prefix, so nothing recorded before the
    /// mutation is lost.
    fn apply_snapshot_survivors(&mut self, snap: &RunSnapshot) -> anyhow::Result<()> {
        self.apply_snapshot_inner(snap, true)
    }

    fn apply_snapshot_inner(
        &mut self,
        snap: &RunSnapshot,
        survivors_only: bool,
    ) -> anyhow::Result<()> {
        let checkpointer = &self.checkpointer;
        let state = self
            .state
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("snapshot restore without a run state"))?;
        if !survivors_only {
            for (vid, regions) in &snap.regions {
                let Some(loc) = state.mapping.placement(*vid) else {
                    continue;
                };
                let current = state.region_digests.get(vid).cloned().unwrap_or_default();
                let table = scamp::region_table(&state.sim, loc)?;
                for (id, (len, digest)) in regions {
                    if current.get(id).copied() == Some((*len, *digest)) || *len == 0 {
                        continue;
                    }
                    let (addr, alen) = table.get(id).copied().ok_or_else(|| {
                        anyhow::anyhow!(
                            "snapshot region {id} of vertex {vid:?} has no allocation at restore"
                        )
                    })?;
                    anyhow::ensure!(
                        alen == *len,
                        "snapshot region {id} of vertex {vid:?} is {len} bytes but the \
                         loaded allocation is {alen} (regenerated data changed size)"
                    );
                    let bytes = checkpointer
                        .as_ref()
                        .ok_or_else(|| {
                            anyhow::anyhow!("snapshot restore needs a checkpoint store for blobs")
                        })?
                        .get_blob(*digest)?;
                    scamp::write_sdram(&mut state.sim, loc.chip(), addr, &bytes)?;
                    state
                        .region_digests
                        .entry(*vid)
                        .or_default()
                        .insert(*id, (*len, *digest));
                }
            }
        }
        for (vid, core_snap) in &snap.cores {
            let Some(loc) = state.mapping.placement(*vid) else {
                continue;
            };
            if survivors_only
                && state.region_digests.get(vid) != snap.regions.get(vid)
            {
                continue;
            }
            scamp::restore_core(&mut state.sim, loc, core_snap, snap.tick)?;
        }
        state.recordings = snap
            .host_recordings
            .iter()
            .filter(|((vid, _), _)| state.mapping.placement(*vid).is_some())
            .map(|(k, v)| (*k, v.clone()))
            .collect();
        state.ticks_done = snap.tick;
        scamp::signal_resume(&mut state.sim)?;
        Ok(())
    }

    /// Capture and return a [`RunSnapshot`] of the paused run — the
    /// suspend half of surviving a process restart. The snapshot (and
    /// its region blobs) are also written to the checkpoint store; with
    /// a [`super::checkpoint::FileCheckpointer`] installed, a new
    /// process can rebuild the graphs and [`Self::resume_from`] it.
    pub fn suspend(&mut self) -> anyhow::Result<RunSnapshot> {
        anyhow::ensure!(
            self.state.is_some(),
            "suspend before any run (nothing to capture)"
        );
        let revisions = self.graph_revisions();
        anyhow::ensure!(
            self.mapped_revisions == Some(revisions),
            "graph mutated since the last run; run_ticks() to reconcile before suspending"
        );
        if self.checkpointer.is_none() {
            self.checkpointer = Some(Box::new(MemoryCheckpointer::new()));
        }
        let key_cursor = self.pipeline.key_cursor().unwrap_or(0);
        let extraction = self.config.extraction;
        let store = self
            .checkpointer
            .as_deref_mut()
            .ok_or_else(|| anyhow::anyhow!("suspend without a checkpoint store"))?;
        let state = self
            .state
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("suspend before any run (nothing to capture)"))?;
        let tick = state.ticks_done;
        Self::capture_snapshot(state, tick, revisions, key_cursor, extraction, store)
    }

    /// Rebuild a run from a [`RunSnapshot`] — the resume half of
    /// surviving a process restart. The graphs must already be rebuilt
    /// to the exact revisions the snapshot was taken at; the mapping
    /// pipeline is re-seeded with the snapshot's placements and key
    /// allocations (every vertex lands back on its core), the machine
    /// is mapped and loaded as a first run, and the snapshot is applied
    /// on top. The next [`Self::run_ticks`] continues from
    /// `snapshot.tick` in the original Figure-9 cycle unit.
    pub fn resume_from(&mut self, snap: &RunSnapshot) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.state.is_none(),
            "resume_from over an active run; reset() first"
        );
        anyhow::ensure!(
            self.graph_revisions() == snap.revisions,
            "graphs at revisions {:?} do not match the snapshot's {:?} — rebuild \
             them exactly as they were when the snapshot was taken",
            self.graph_revisions(),
            snap.revisions
        );
        self.pipeline.clear();
        let mut placements = Placements::default();
        for (vid, loc) in &snap.placements {
            placements.insert(*vid, *loc)?;
        }
        self.pipeline.seed(placements, snap.keys.clone(), snap.key_cursor);
        // Map/load exactly like a first run, but plan for one original
        // cycle unit (so the rebuilt plan keeps the suspended run's
        // Figure-9 cadence) and do not drive any ticks.
        self.prepare_run(snap.steps_per_cycle.max(1))?;
        self.apply_snapshot(snap)?;
        let state = self
            .state
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("resume_from lost the run state"))?;
        if !snap.pending_chaos.is_empty() {
            state.chaos = Some(ChaosPlan { events: snap.pending_chaos.clone() });
        }
        Ok(())
    }

    /// Unload every loaded application core that is neither a current
    /// placement nor a quarantined (excluded) core: the cleanup sweep
    /// between a failed heal attempt and its full-re-map retry, removing
    /// apps the failed attempt installed before erroring.
    fn unload_unmapped_cores(&mut self) -> anyhow::Result<()> {
        let state = self
            .state
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("cleanup without a run state"))?;
        let loaded: Vec<CoreLocation> = scamp::core_states(&state.sim).into_keys().collect();
        for loc in loaded {
            if state.mapping.placements.at(loc).is_none()
                && !state.excluded_cores.contains(&loc)
            {
                scamp::unload_app(&mut state.sim, loc)?;
            }
        }
        Ok(())
    }

    /// One supervisor poll (the §6.3.5 state scan run *during* the run):
    /// classify every user vertex's core as healthy, failed (RTE /
    /// watchdog — IOBUF read back immediately), or unreachable (its whole
    /// chip vanished from the scan), and check the routers for packets
    /// lost to links that died under installed routes.
    fn supervisor_poll(state: &mut RunState) -> anyhow::Result<Vec<FaultFinding>> {
        let states = scamp::core_states(&state.sim);
        let mut findings = Vec::new();
        let mut unreachable: BTreeMap<ChipCoord, Vec<String>> = BTreeMap::new();
        let mut failed: Vec<(CoreLocation, String, bool)> = Vec::new();
        for (label, loc) in &state.labels {
            match states.get(loc) {
                Some(CoreState::RunTimeError) => failed.push((*loc, label.clone(), false)),
                Some(CoreState::Watchdog) => failed.push((*loc, label.clone(), true)),
                Some(_) => {}
                None => {
                    unreachable.entry(loc.chip()).or_default().push(label.clone());
                }
            }
        }
        for (loc, label, watchdog) in failed {
            let iobuf = scamp::read_iobuf(&mut state.sim, loc).unwrap_or_default();
            findings.push(FaultFinding::CoreFailure { loc, label, watchdog, iobuf });
        }
        for (chip, labels) in unreachable {
            findings.push(FaultFinding::UnreachableChip { chip, labels });
        }
        let lost = state.sim.total_router_stats().mc_dead_link;
        if lost > state.link_loss_seen {
            findings.push(FaultFinding::LinkLoss { packets: lost - state.link_loss_seen });
            state.link_loss_seen = lost;
        }
        Ok(findings)
    }

    /// Self-heal around the findings: quarantine the failed cores,
    /// re-discover the degraded machine, re-map incrementally (survivor
    /// vertices stay pinned; the placer treats the newly-dead chips as
    /// forbidden), reload the displaced vertices, and leave the run
    /// state ready to restart. With checkpointing on, the restart
    /// resumes from the newest [`RunSnapshot`] — every stored snapshot
    /// was captured at a clean poll, so it predates the fault — and
    /// replays only the tail; without, it replays the *whole* tick
    /// history from tick 0. Infeasible incremental maps fall back to a
    /// cleared pipeline — a full re-map on the degraded machine. The
    /// whole pass is recorded as a [`HealReport`].
    fn heal(&mut self, findings: &[FaultFinding], total_ticks: u64) -> anyhow::Result<()> {
        let t0 = Instant::now();
        let fault_descs: Vec<String> = findings.iter().map(|f| f.describe()).collect();
        let restore = self.newest_snapshot();
        let replay_ticks = restore
            .as_ref()
            .map(|s| total_ticks.saturating_sub(s.tick))
            .unwrap_or(total_ticks);
        let (machine, forbidden) = {
            let state = self
                .state
                .as_mut()
                .ok_or_else(|| anyhow::anyhow!("heal without a run state"))?;
            for f in findings {
                if let FaultFinding::CoreFailure { loc, .. } = f {
                    state.excluded_cores.insert(*loc);
                }
            }
            // The bulk data plane is retired by a heal: its stream
            // routes and per-chip writer/reader assignments were planned
            // against the healthy machine, and replaying its lossless
            // recovery protocol into a dead chip would never complete.
            // Loading/extraction fall back to the SCAMP paths; the
            // plane's (benign, still-loaded) system cores are
            // quarantined so nothing gets placed on top of them.
            if let Some(fp) = state.fast_path.take() {
                state.excluded_cores.extend(fp.system_cores());
                state.data_plane_error = Some(
                    "bulk data plane retired by self-heal (stream routes \
                     predate the fault); SCAMP fallback in use"
                        .to_string(),
                );
            }
            // Boards whose host link escalated (or sits in a silent
            // chaos episode) are powered off before re-discovery: every
            // chip on the board becomes an ordinary dead chip, so the
            // existing forbidden-resource machinery — placement, routing,
            // rediscovery exclusion, core silencing — maps around the
            // dark board exactly as it does around chip death.
            for board in state.sim.wire_unreachable_boards() {
                // A shared session only owns its partition: another
                // tenant's dark board is not ours to power off (their
                // own heal will take it down inside their scope).
                if !state.sim.in_scope(board) {
                    continue;
                }
                state.sim.power_off_board(board)?;
            }
            // Re-discover while the failed cores still show their failed
            // states (the persistent quarantine covers later heals, after
            // unloading has reset them to Idle).
            let machine =
                scamp::rediscover_machine(&mut state.sim, &state.excluded_cores);
            for f in findings {
                if let FaultFinding::CoreFailure { loc, .. } = f {
                    if scamp::core_state(&state.sim, *loc)
                        .is_ok_and(|s| s != CoreState::Idle)
                    {
                        scamp::unload_app(&mut state.sim, *loc)?;
                    }
                }
            }
            (machine, state.sim.dead_chips())
        };
        let summary = match self.remap_and_reload(replay_ticks, machine.clone(), &forbidden) {
            Ok(s) => s,
            Err(e) => {
                // Same contract as reconcile: infeasibility is never
                // silent, and the fallback is a genuine from-scratch map
                // (on the degraded machine — the healthy one is gone).
                // The failed attempt may have installed vertices at new
                // cores before erroring; sweep those ghosts out first so
                // the retry cannot double-load or leave duplicates
                // running.
                self.remap_note =
                    Some(format!("heal fell back to a full re-map: {e}"));
                self.unload_unmapped_cores()?;
                self.pipeline.clear();
                self.remap_and_reload(replay_ticks, machine, &forbidden)?
            }
        };
        // Lay the snapshot over the freshly reloaded machine: survivors
        // get their evolving state back in place; displaced vertices got
        // a fresh install at the new core above and now get the same
        // state restored there. Fired chaos events were drained from the
        // live plan already, so nothing re-fires during the tail replay.
        if let Some(snap) = &restore {
            self.apply_snapshot(snap)?;
        }
        let state = self.state.as_mut().ok_or_else(|| {
            anyhow::anyhow!("run state lost while recording a heal of: {}", fault_descs.join("; "))
        })?;
        let report = HealReport {
            faults: fault_descs,
            vertices_moved: summary.vertices_moved,
            tables_rewritten: summary.tables_rewritten,
            map_elapsed_us: summary.map_elapsed_us,
            heal_elapsed_us: t0.elapsed().as_micros() as u64,
            stages_cached: summary.stages_cached,
            stages_rerun: summary.stages_rerun,
            restored_from_tick: restore.as_ref().map(|s| s.tick),
            wire: state.sim.wire_stats(),
        };
        self.bus.emit(RunEvent::Healed {
            faults: report.faults.len(),
            vertices_moved: report.vertices_moved,
            restored_from_tick: report.restored_from_tick,
            heal_elapsed_us: report.heal_elapsed_us,
        });
        state.heal_reports.push(report);
        Ok(())
    }

    fn extract_recordings(
        state: &mut RunState,
        extraction: ExtractionMethod,
    ) -> anyhow::Result<()> {
        let vids: Vec<VertexId> = state.plan.recording_bytes.keys().copied().collect();
        // Split the pending channels between the paths first, so the
        // fast reads batch into one per-board-parallel drain.
        let mut fast: Vec<(VertexId, CoreLocation, u32, usize)> = Vec::new();
        let mut slow: Vec<(VertexId, CoreLocation, u32, usize)> = Vec::new();
        for vid in vids {
            let loc = state
                .mapping
                .placement(vid)
                .ok_or_else(|| anyhow::anyhow!("recording vertex {vid:?} unplaced"))?;
            let (addr, written, _) = scamp::recording_info(&state.sim, loc, 0)?;
            if written == 0 {
                continue;
            }
            let use_fast = extraction == ExtractionMethod::FastMulticast
                && state
                    .fast_path
                    .as_ref()
                    .is_some_and(|fp| fp.has_reader(loc.chip()));
            if use_fast {
                fast.push((vid, loc, addr, written));
            } else {
                slow.push((vid, loc, addr, written));
            }
        }
        if !fast.is_empty() {
            let reqs: Vec<(ChipCoord, u32, usize)> = fast
                .iter()
                .map(|(_, loc, addr, written)| (loc.chip(), *addr, *written))
                .collect();
            let fp = state
                .fast_path
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("fast reads without a data plane"))?;
            let datas = fp.read_many(&mut state.sim, &reqs)?;
            for ((vid, loc, _, _), data) in fast.iter().zip(datas) {
                state
                    .recordings
                    .entry((*vid, 0))
                    .or_default()
                    .extend_from_slice(&data);
                scamp::clear_recording(&mut state.sim, *loc, 0)?;
            }
        }
        for (vid, loc, addr, written) in slow {
            let data = scamp::read_sdram(&mut state.sim, loc.chip(), addr, written)?;
            state
                .recordings
                .entry((vid, 0))
                .or_default()
                .extend_from_slice(&data);
            scamp::clear_recording(&mut state.sim, loc, 0)?;
        }
        Ok(())
    }

    /// §6.3.5 failure detection: error if any core ended in RTE (or
    /// stalled into the watchdog).
    fn check_completion(&mut self) -> anyhow::Result<()> {
        let state = self
            .state
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("completion check without a run state"))?;
        let bad: Vec<String> = scamp::core_states(&state.sim)
            .into_iter()
            .filter(|(_, s)| matches!(s, CoreState::RunTimeError | CoreState::Watchdog))
            .map(|(l, _)| l.to_string())
            .collect();
        if !bad.is_empty() {
            let report = self.provenance();
            anyhow::bail!(
                "cores in error state: {bad:?}; anomalies: {:?}",
                report.anomalies
            );
        }
        Ok(())
    }

    // -- results (§6.4) ------------------------------------------------------

    /// Recorded bytes of one machine vertex (channel 0).
    pub fn recording(&self, v: VertexId) -> &[u8] {
        self.state
            .as_ref()
            .and_then(|s| s.recordings.get(&(v, 0)))
            .map(|d| d.as_slice())
            .unwrap_or(&[])
    }

    /// Recordings of every machine vertex an application vertex was
    /// split into, with their atom slices.
    pub fn app_recordings(&self, v: AppVertexId) -> Vec<(Slice, &[u8])> {
        let Some(state) = &self.state else { return Vec::new() };
        let Some(gm) = &state.graph_mapping else { return Vec::new() };
        let Some(mvs) = gm.machine_vertices_of.get(&v) else {
            return Vec::new();
        };
        mvs.iter()
            .map(|(mv, slice)| {
                (
                    *slice,
                    state
                        .recordings
                        .get(&(*mv, 0))
                        .map(|d| d.as_slice())
                        .unwrap_or(&[]),
                )
            })
            .collect()
    }

    /// The machine vertices (and slices) of an application vertex.
    pub fn machine_vertices_of(&self, v: AppVertexId) -> Vec<(VertexId, Slice)> {
        self.state
            .as_ref()
            .and_then(|s| s.graph_mapping.as_ref())
            .and_then(|gm| gm.machine_vertices_of.get(&v).cloned())
            .unwrap_or_default()
    }

    pub fn provenance(&self) -> ProvenanceReport {
        match &self.state {
            Some(state) => {
                let mut report = ProvenanceReport::collect(&state.sim, &state.labels);
                if let Some(e) = &state.data_plane_error {
                    report.anomalies.push(format!(
                        "bulk data plane unavailable (SCAMP fallback in use): {e}"
                    ));
                }
                if let Some(note) = &self.remap_note {
                    report.anomalies.push(note.clone());
                }
                if let Some(note) = &self.discard_note {
                    report.anomalies.push(note.clone());
                }
                for heal in &state.heal_reports {
                    for fault in &heal.faults {
                        report
                            .anomalies
                            .push(format!("healed around runtime fault: {fault}"));
                    }
                }
                for (t, fault) in &state.sim.fault_log {
                    report
                        .anomalies
                        .push(format!("fault injected at {t} ns: {fault}"));
                }
                report.remap = state.last_remap.clone();
                report.heals = state.heal_reports.clone();
                // Mirror anomalies onto the bus, once per distinct text
                // (provenance is re-collected freely; the bus stream
                // must not repeat).
                if self.bus.has_sinks() {
                    for a in &report.anomalies {
                        self.bus.emit_anomaly(a);
                    }
                }
                report
            }
            None => ProvenanceReport::default(),
        }
    }

    /// What the most recent mapping pass re-ran vs. served from the
    /// stage cache (§6.5 / DESIGN.md §7). `None` before the first run.
    pub fn remap_report(&self) -> Option<&RemapReport> {
        self.state.as_ref().and_then(|s| s.last_remap.as_ref())
    }

    pub fn database(&self) -> Option<&MappingDatabase> {
        self.state.as_ref().map(|s| &s.database)
    }

    pub fn mapping(&self) -> Option<&Mapping> {
        self.state.as_ref().map(|s| &s.mapping)
    }

    pub fn machine(&self) -> Option<&Machine> {
        self.state.as_ref().map(|s| &s.sim.machine)
    }

    /// Direct access to the simulated machine (live I/O, tests).
    pub fn sim_mut(&mut self) -> Option<&mut SimMachine> {
        self.state.as_mut().map(|s| &mut s.sim)
    }

    pub fn run_graph(&self) -> Option<&MachineGraph> {
        self.state.as_ref().map(|s| &s.run_graph)
    }

    pub fn ticks_done(&self) -> u64 {
        self.state.as_ref().map(|s| s.ticks_done).unwrap_or(0)
    }

    pub fn runtime(&self) -> Option<&Rc<Runtime>> {
        self.runtime.as_ref()
    }

    // -- closing (§6.6) ------------------------------------------------------

    /// Stop the cores and release the machine; recordings survive until
    /// `reset`, mirroring §6.6's "recorded data will no longer be
    /// available" on the machine itself.
    pub fn stop(&mut self) -> anyhow::Result<()> {
        if let Some(state) = &mut self.state {
            scamp::signal_stop(&mut state.sim)?;
        }
        Ok(())
    }

    /// Forget the run entirely (graphs survive; the next run remaps).
    /// Provably from-scratch: the persistent pipeline state (stage
    /// cache + prior stage outputs) is dropped and both graphs' change
    /// journals are cleared, so nothing of the previous mapping can
    /// leak into the next run.
    pub fn reset(&mut self) {
        self.park_lent_sim();
        self.state = None;
        self.pipeline.clear();
        self.mapped_revisions = None;
        self.remap_note = None;
        self.discard_note = None;
        self.pending_chaos = None;
        // In-memory snapshots die with the run; a FileCheckpointer's
        // files survive on disk for cross-process resume_from.
        self.checkpointer = None;
        self.machine_graph.clear_journal();
        self.app_graph.clear_journal();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::conway::{ConwayCellVertex, STATE_PARTITION};
    use crate::front::config::{BootFaults, MachineSpec};

    /// Build an r x c Conway machine graph.
    fn conway_graph(tools: &mut SpiNNTools, rows: u32, cols: u32, live: &[(u32, u32)]) -> Vec<VertexId> {
        let mut ids = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                let alive = live.contains(&(r, c));
                ids.push(
                    tools
                        .add_machine_vertex(ConwayCellVertex::arc(r, c, alive))
                        .unwrap(),
                );
            }
        }
        let idx = |r: i64, c: i64| -> Option<usize> {
            (r >= 0 && c >= 0 && r < rows as i64 && c < cols as i64)
                .then_some((r * cols as i64 + c) as usize)
        };
        for r in 0..rows as i64 {
            for c in 0..cols as i64 {
                for dr in -1..=1 {
                    for dc in -1..=1 {
                        if (dr, dc) == (0, 0) {
                            continue;
                        }
                        if let Some(n) = idx(r + dr, c + dc) {
                            tools
                                .add_machine_edge(
                                    ids[idx(r, c).unwrap()],
                                    ids[n],
                                    STATE_PARTITION,
                                )
                                .unwrap();
                        }
                    }
                }
            }
        }
        ids
    }

    #[test]
    fn full_flow_conway_blinker() {
        // E3: the complete Figure-8 flow on a real (small) workload.
        let mut tools = SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn3)).unwrap();
        let ids = conway_graph(&mut tools, 5, 5, &[(2, 1), (2, 2), (2, 3)]);
        tools.run_ticks(4).unwrap();
        // Blinker oscillates with period 2: vertical at odd steps.
        let state = |r: u32, c: u32| tools.recording(ids[(r * 5 + c) as usize]);
        assert_eq!(state(2, 2), &[1, 1, 1, 1], "centre always alive");
        assert_eq!(state(2, 1), &[1, 0, 1, 0], "wing flips");
        assert_eq!(state(1, 2), &[0, 1, 0, 1], "vertical wing appears");
        assert_eq!(state(0, 0), &[0, 0, 0, 0], "corner stays dead");
        // no dropped packets on this tiny graph
        assert_eq!(tools.provenance().total_dropped(), 0);
    }

    #[test]
    fn resume_continues_the_oscillation() {
        // E3/§6.5: run, return control, resume without remapping.
        let mut tools = SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn3)).unwrap();
        let ids = conway_graph(&mut tools, 5, 5, &[(2, 1), (2, 2), (2, 3)]);
        tools.run_ticks(2).unwrap();
        assert_eq!(tools.ticks_done(), 2);
        tools.run_ticks(2).unwrap();
        assert_eq!(tools.ticks_done(), 4);
        let wing = tools.recording(ids[(2 * 5 + 1) as usize]);
        assert_eq!(wing, &[1, 0, 1, 0]);
    }

    #[test]
    fn mapping_threads_do_not_change_results() {
        let run = |threads: usize| -> Vec<u8> {
            let mut tools = SpiNNTools::new(
                ToolsConfig::new(MachineSpec::Spinn3).with_mapping_threads(threads),
            )
            .unwrap();
            let ids = conway_graph(&mut tools, 5, 5, &[(2, 1), (2, 2), (2, 3)]);
            assert_eq!(tools.mapping_threads(), threads);
            tools.run_ticks(4).unwrap();
            tools.recording(ids[(2 * 5 + 1) as usize]).to_vec()
        };
        let serial = run(1);
        assert_eq!(serial, run(4), "threaded mapping changed the simulation");
        assert_eq!(serial, &[1, 0, 1, 0]);
    }

    #[test]
    fn mapping_threads_locked_once_running() {
        let mut tools = SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn3)).unwrap();
        tools.set_mapping_threads(2).unwrap();
        conway_graph(&mut tools, 3, 3, &[]);
        tools.run_ticks(1).unwrap();
        assert!(tools.set_mapping_threads(4).is_err());
        tools.reset();
        assert!(tools.set_mapping_threads(4).is_ok());
    }

    #[test]
    fn graph_changes_after_run_trigger_incremental_remap() {
        // §6.5's "graph changed" branch: mutations between runs are
        // journalled and the next run reconciles incrementally instead
        // of erroring (the pre-incremental behaviour) or re-running the
        // whole pipeline.
        let mut tools = SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn3)).unwrap();
        let ids = conway_graph(&mut tools, 3, 3, &[(1, 0), (1, 1), (1, 2)]);
        tools.run_ticks(4).unwrap();
        let first = tools.remap_report().unwrap().clone();
        assert_eq!(first.stages_cached, 0, "first map is full");

        // Add a vertex wired into the corner: placement and routing
        // re-run, but e.g. the tag allocator is clean — strictly fewer
        // stages than the total.
        let extra = tools
            .add_machine_vertex(ConwayCellVertex::arc(9, 9, true))
            .unwrap();
        tools.add_machine_edge(extra, ids[0], STATE_PARTITION).unwrap();
        tools.run_ticks(4).unwrap();
        let report = tools.remap_report().unwrap().clone();
        assert!(
            report.stages_rerun < report.stage_count(),
            "small delta must reuse cached stages: {report:?}"
        );
        assert_eq!(report.stage_count(), first.stage_count());
        assert_eq!(tools.ticks_done(), 4, "reconcile restarts from tick 0");

        // Equivalence: a fresh instance built directly with the final
        // graph records byte-identical behaviour.
        let mut fresh = SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn3)).unwrap();
        let fids = conway_graph(&mut fresh, 3, 3, &[(1, 0), (1, 1), (1, 2)]);
        let fextra = fresh
            .add_machine_vertex(ConwayCellVertex::arc(9, 9, true))
            .unwrap();
        fresh.add_machine_edge(fextra, fids[0], STATE_PARTITION).unwrap();
        fresh.run_ticks(4).unwrap();
        for (a, b) in ids.iter().zip(&fids) {
            assert_eq!(tools.recording(*a), fresh.recording(*b));
        }
        assert_eq!(tools.recording(extra), fresh.recording(fextra));
        assert_eq!(tools.recording(extra).len(), 4);
    }

    #[test]
    fn reset_clears_journal_and_stage_cache() {
        // Regression (reset bugfix): a reset run must be provably
        // from-scratch — no cached stage may survive reset, and the
        // delta journal must be emptied.
        let mut tools = SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn3)).unwrap();
        let ids = conway_graph(&mut tools, 3, 3, &[(1, 1)]);
        tools.run_ticks(2).unwrap();
        tools.remove_machine_vertex(ids[0]).unwrap();
        tools.reset();
        assert!(tools.machine_graph.journal().is_empty(), "journal survived reset");
        assert!(tools.pipeline.is_fresh(), "stage cache survived reset");
        tools.run_ticks(2).unwrap();
        let report = tools.remap_report().unwrap();
        assert_eq!(report.stages_cached, 0, "reset run must not reuse stages");
        assert_eq!(tools.ticks_done(), 2);
    }

    #[test]
    fn remove_vertex_reconciles_and_restarts() {
        // Killing one wing of the blinker leaves a 2-cell pair that
        // dies out — compare against a fresh build of the same graph.
        let mut tools = SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn3)).unwrap();
        let ids = conway_graph(&mut tools, 3, 3, &[(1, 0), (1, 1), (1, 2)]);
        tools.run_ticks(2).unwrap();
        tools.remove_machine_vertex(ids[(1 * 3 + 0) as usize]).unwrap();
        tools.run_ticks(3).unwrap();
        // Remaining pair: both alive at step 1 (initial), dead after.
        assert_eq!(tools.recording(ids[(1 * 3 + 1) as usize]), &[1, 0, 0]);
        assert_eq!(tools.recording(ids[(1 * 3 + 2) as usize]), &[1, 0, 0]);
        // The removed vertex has no recordings after the reconcile.
        assert!(tools.recording(ids[(1 * 3 + 0) as usize]).is_empty());
        let report = tools.remap_report().unwrap();
        assert!(report.stages_rerun < report.stage_count(), "{report:?}");
    }

    #[test]
    fn database_contains_placements_and_keys() {
        let mut tools = SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn3)).unwrap();
        conway_graph(&mut tools, 3, 3, &[(1, 1)]);
        tools.run_ticks(1).unwrap();
        let db = tools.database().unwrap();
        assert!(db.placement_of("cell_0_0").is_some());
        assert!(db.key_of("cell_1_1", STATE_PARTITION).is_some());
    }

    #[test]
    fn mixing_graphs_is_an_error() {
        let mut tools = SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn3)).unwrap();
        tools
            .add_machine_vertex(ConwayCellVertex::arc(0, 0, true))
            .unwrap();
        tools
            .add_application_vertex(crate::apps::poisson::PoissonSourceVertex::arc(
                "p", 10, 5.0, 1, false,
            ))
            .unwrap();
        assert!(tools.run_ticks(1).is_err());
    }

    #[test]
    fn fast_data_plane_loading_matches_scamp_loading() {
        // E12 correctness half: the same workload, loaded over the
        // data-in streams and extracted over per-board readers, produces
        // byte-identical recordings to the pure-SCAMP flow.
        // 3x3 leaves room on the Ethernet chip for all four plane cores.
        let run = |config: ToolsConfig| -> Vec<Vec<u8>> {
            let mut tools = SpiNNTools::new(config).unwrap();
            let ids = conway_graph(&mut tools, 3, 3, &[(1, 0), (1, 1), (1, 2)]);
            tools.run_ticks(4).unwrap();
            ids.iter().map(|v| tools.recording(*v).to_vec()).collect()
        };
        let scamp = run(ToolsConfig::new(MachineSpec::Spinn3));
        let fast = run(ToolsConfig::new(MachineSpec::Spinn3)
            .with_loading(LoadMethod::FastMulticast)
            .with_extraction(ExtractionMethod::FastMulticast));
        let batched =
            run(ToolsConfig::new(MachineSpec::Spinn3).with_loading(LoadMethod::ScampBatched));
        assert_eq!(scamp, fast, "data plane changed the simulation");
        assert_eq!(scamp, batched, "batched loading changed the simulation");
    }

    #[test]
    fn failed_plane_install_lands_in_provenance() {
        // Pack every application core so the plane has nowhere to live:
        // the run must still succeed over SCAMP, and the report must say
        // why the fast path is absent (no silent `.ok()` fallback).
        let mut tools = SpiNNTools::new(
            ToolsConfig::new(MachineSpec::Spinn3).with_extraction(ExtractionMethod::FastMulticast),
        )
        .unwrap();
        let ids = conway_graph(&mut tools, 4, 17, &[(1, 5)]);
        assert_eq!(ids.len(), 68, "exactly the machine's application cores");
        tools.run_ticks(2).unwrap();
        let report = tools.provenance();
        assert!(
            report
                .anomalies
                .iter()
                .any(|a| a.contains("bulk data plane unavailable")),
            "anomalies: {:?}",
            report.anomalies
        );
    }

    #[test]
    fn supervisor_abort_surfaces_iobuf_text() {
        use crate::simulator::{ChaosPlan, Fault};
        let mut tools = SpiNNTools::new(
            ToolsConfig::new(MachineSpec::Spinn3).with_supervision(SupervisorConfig {
                poll_interval_ticks: 1,
                policy: HealPolicy::Abort,
                max_heals: 4,
            }),
        )
        .unwrap();
        let ids = conway_graph(&mut tools, 3, 3, &[(1, 0), (1, 1), (1, 2)]);
        tools.run_ticks(2).unwrap();
        let victim = tools.mapping().unwrap().placement(ids[0]).unwrap();
        tools.inject_chaos(ChaosPlan::new().with(4, Fault::CoreRte(victim)));
        let err = tools.run_ticks(4).unwrap_err().to_string();
        assert!(err.contains("aborted by supervisor"), "{err}");
        assert!(err.contains("RTE on core"), "{err}");
        assert!(err.contains("[chaos] RTE injected"), "iobuf text missing: {err}");
    }

    #[test]
    fn supervisor_heals_chip_death_and_reports() {
        use crate::simulator::{ChaosPlan, Fault};
        let mut tools = SpiNNTools::new(
            ToolsConfig::new(MachineSpec::Spinn3)
                .with_supervision(SupervisorConfig::default()),
        )
        .unwrap();
        let ids = conway_graph(&mut tools, 5, 5, &[(2, 1), (2, 2), (2, 3)]);
        // Find which non-boot chip will host vertices, then kill it
        // mid-run. 25 vertices span 2 chips; (1,0) is the second in
        // radial order.
        tools.inject_chaos(ChaosPlan::new().with(2, Fault::ChipDeath((1, 0))));
        tools.run_ticks(4).unwrap();
        // The run healed: one report, with vertices moved off the chip.
        let heals = tools.heal_reports();
        assert_eq!(heals.len(), 1, "expected exactly one heal");
        assert!(heals[0].vertices_moved > 0);
        assert!(heals[0].faults.iter().any(|f| f.contains("unreachable")), "{:?}", heals[0].faults);
        assert!(heals[0].stages_cached > 0, "heal must reuse pipeline stages");
        // Nothing lives on the dead chip; the machine view lost it.
        let mapping = tools.mapping().unwrap();
        for id in &ids {
            assert_ne!(mapping.placement(*id).unwrap().chip(), (1, 0));
        }
        assert!(tools.machine().unwrap().chip((1, 0)).is_none());
        // Post-heal recordings equal a fresh run on the degraded board.
        let mut fresh = SpiNNTools::new(
            ToolsConfig::new(MachineSpec::Spinn3)
                .with_supervision(SupervisorConfig::default())
                .with_boot_faults(BootFaults { chips: vec![(1, 0)], ..Default::default() }),
        )
        .unwrap();
        let fids = conway_graph(&mut fresh, 5, 5, &[(2, 1), (2, 2), (2, 3)]);
        fresh.run_ticks(4).unwrap();
        for (a, b) in ids.iter().zip(&fids) {
            assert_eq!(tools.recording(*a), fresh.recording(*b), "vertex {a:?}");
        }
        // Provenance carries the heal + the injected fault.
        let report = tools.provenance();
        assert_eq!(report.heals.len(), 1);
        assert!(report
            .anomalies
            .iter()
            .any(|a| a.contains("healed around runtime fault")));
    }

    #[test]
    fn unsupervised_chaos_still_fails_the_run() {
        use crate::simulator::{ChaosPlan, Fault};
        // Without supervision the historical contract holds: the failure
        // surfaces as a completion error, not a heal.
        let mut tools = SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn3)).unwrap();
        let ids = conway_graph(&mut tools, 3, 3, &[(1, 1)]);
        let _ = ids;
        tools.inject_chaos(ChaosPlan::new().with(1, Fault::CoreRte(CoreLocation::new(0, 0, 1))));
        let err = tools.run_ticks(3).unwrap_err().to_string();
        assert!(err.contains("error state"), "{err}");
        assert!(tools.heal_reports().is_empty());
    }

    #[test]
    fn too_big_graph_rejected_at_discovery() {
        let mut tools = SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn3)).unwrap();
        for i in 0..100 {
            tools
                .add_machine_vertex(ConwayCellVertex::arc(i, 0, false))
                .unwrap();
        }
        let err = tools.run_ticks(1).unwrap_err().to_string();
        assert!(err.contains("cores"), "{err}");
    }
}
