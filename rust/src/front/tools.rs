//! The [`SpiNNTools`] façade: the full Figure-8 execution flow.

use std::collections::BTreeMap;
use std::rc::Rc;

use crate::apps::AppRegistry;
use crate::graph::{
    AppVertexId, ApplicationGraph, ApplicationVertexImpl, DataGenContext, MachineGraph,
    MachineVertexImpl, Slice, VertexId,
};
use crate::machine::{ChipCoord, CoreLocation, Machine};
use crate::mapping::database::{MappingDatabase, NotificationProtocol};
use crate::mapping::{map_graph_via_engine, GraphMapping, Mapping};
use crate::runtime::Runtime;
use crate::simulator::{scamp, CoreState, SimMachine};

use super::buffer::{plan_run_cycles, RunCyclePlan};
use super::config::{ExtractionMethod, LoadMethod, ToolsConfig};
use super::extraction::{DataPlaneOptions, FastPath};
use super::provenance::ProvenanceReport;

/// Everything that exists once a graph has been mapped and loaded.
struct RunState {
    sim: SimMachine,
    run_graph: MachineGraph,
    graph_mapping: Option<GraphMapping>,
    mapping: Mapping,
    plan: RunCyclePlan,
    fast_path: Option<FastPath>,
    /// Why the bulk data plane could not be installed, when it was
    /// wanted but unavailable — surfaced through the provenance report
    /// rather than silently falling back to SCAMP.
    data_plane_error: Option<String>,
    /// Host-side store of extracted recordings: (vertex, channel) -> data.
    recordings: BTreeMap<(VertexId, u32), Vec<u8>>,
    labels: Vec<(String, CoreLocation)>,
    ticks_done: u64,
    database: MappingDatabase,
}

/// The SpiNNTools engine (Figure 8): setup → graphs → run → results.
pub struct SpiNNTools {
    config: ToolsConfig,
    machine_graph: MachineGraph,
    app_graph: ApplicationGraph,
    runtime: Option<Rc<Runtime>>,
    registry: AppRegistry,
    state: Option<RunState>,
    pub notifications: NotificationProtocol,
}

impl SpiNNTools {
    /// Setup (§6.1). Opens the PJRT runtime if the config names an
    /// artifact directory.
    pub fn new(config: ToolsConfig) -> anyhow::Result<Self> {
        let runtime = match &config.artifacts_dir {
            Some(dir) => Some(Rc::new(Runtime::open(dir)?)),
            None => None,
        };
        let registry = AppRegistry::standard(runtime.clone());
        Ok(Self {
            config,
            machine_graph: MachineGraph::new(),
            app_graph: ApplicationGraph::new(),
            runtime,
            registry,
            state: None,
            notifications: NotificationProtocol::default(),
        })
    }

    // -- graph creation (§6.2) ---------------------------------------------

    pub fn add_machine_vertex(
        &mut self,
        v: std::sync::Arc<dyn MachineVertexImpl>,
    ) -> anyhow::Result<VertexId> {
        self.ensure_not_running("add vertices")?;
        Ok(self.machine_graph.add_vertex(v))
    }

    pub fn add_machine_edge(
        &mut self,
        pre: VertexId,
        post: VertexId,
        partition: &str,
    ) -> anyhow::Result<()> {
        self.ensure_not_running("add edges")?;
        self.machine_graph.add_edge(pre, post, partition);
        Ok(())
    }

    pub fn add_application_vertex(
        &mut self,
        v: std::sync::Arc<dyn ApplicationVertexImpl>,
    ) -> anyhow::Result<AppVertexId> {
        self.ensure_not_running("add vertices")?;
        Ok(self.app_graph.add_vertex(v))
    }

    pub fn add_application_edge(
        &mut self,
        pre: AppVertexId,
        post: AppVertexId,
        partition: &str,
        payload: Option<std::sync::Arc<dyn std::any::Any + Send + Sync>>,
    ) -> anyhow::Result<()> {
        self.ensure_not_running("add edges")?;
        self.app_graph.add_edge(pre, post, partition, payload);
        Ok(())
    }

    /// Register a custom binary (users extend the vertex classes, §6.2).
    pub fn register_binary(
        &mut self,
        name: &str,
        factory: impl Fn() -> Box<dyn crate::simulator::CoreApp> + 'static,
    ) {
        self.registry.register(name, factory);
    }

    /// Change the mapping worker-pool width (see
    /// [`ToolsConfig::with_mapping_threads`]). A user-level option in the
    /// §6.1 sense: it never changes mapping *results*, only host
    /// wall-clock, so unlike graph edits it is allowed before any run —
    /// but not between runs, since mapping has already happened.
    pub fn set_mapping_threads(&mut self, threads: usize) -> anyhow::Result<()> {
        self.ensure_not_running("change mapping threads")?;
        self.config.mapping.options.threads = threads;
        Ok(())
    }

    /// The configured mapping worker-pool width.
    pub fn mapping_threads(&self) -> usize {
        self.config.mapping.options.threads
    }

    fn ensure_not_running(&self, what: &str) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.state.is_none(),
            "cannot {what} after a run has started; reset() first (graph \
             changes require a remap, §6.5)"
        );
        Ok(())
    }

    // -- graph execution (§6.3) --------------------------------------------

    /// Run for a simulated duration in milliseconds.
    pub fn run_ms(&mut self, ms: u64) -> anyhow::Result<()> {
        let ticks = ms * 1000 / self.config.timestep_us as u64;
        self.run_ticks(ticks.max(1))
    }

    /// Run for a number of timesteps. The first call performs machine
    /// discovery, mapping, data generation and loading; later calls
    /// resume (§6.5) in the established Figure-9 cycle unit.
    pub fn run_ticks(&mut self, ticks: u64) -> anyhow::Result<()> {
        if self.state.is_none() {
            self.first_run(ticks)
        } else {
            self.resume_run(ticks)
        }
    }

    fn first_run(&mut self, ticks: u64) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.machine_graph.n_vertices() == 0 || self.app_graph.n_vertices() == 0,
            "it is an error to add vertices to both the application and \
             machine graphs (§6.2)"
        );

        // ---- machine discovery (§6.3.1) --------------------------------
        let template = self.config.machine.template();

        // Application graphs are first converted to a machine graph to
        // size the machine (§6.3.1) — the same split is then used on.
        let (run_graph, graph_mapping) = if self.app_graph.n_vertices() > 0 {
            let (g, m) = crate::mapping::splitter::split_graph(&self.app_graph, &template)?;
            (g, Some(m))
        } else {
            (self.machine_graph.clone(), None)
        };

        // Virtual chips for device vertices (§5.1/§7.2).
        let mut builder = self.config.machine.build();
        let mut next_virtual = (template.width + 1, template.height + 1);
        for (_, vertex) in run_graph.vertices() {
            if let Some(vl) = vertex.virtual_link() {
                builder = builder.virtual_chip(next_virtual, vl.attached_to, vl.direction);
                next_virtual = (next_virtual.0 + 1, next_virtual.1 + 1);
            }
        }
        let machine = builder.build();
        anyhow::ensure!(
            run_graph.n_vertices() <= machine.n_application_cores(),
            "graph needs {} cores; machine has {}",
            run_graph.n_vertices(),
            machine.n_application_cores()
        );
        let mut sim = SimMachine::boot(machine.clone(), self.config.sim.clone());

        // ---- mapping (§6.3.2), on the Figure-10 engine ------------------
        let (mapping, _workflow) =
            map_graph_via_engine(&machine, &run_graph, &self.config.mapping)?;

        // ---- data generation (§6.3.3) -----------------------------------
        let mut region_data: BTreeMap<VertexId, BTreeMap<u32, Vec<u8>>> = BTreeMap::new();
        let mut data_bytes: BTreeMap<VertexId, u64> = BTreeMap::new();
        for (vid, vertex) in run_graph.vertices() {
            if vertex.virtual_link().is_some() {
                continue;
            }
            let placement = mapping
                .placement(vid)
                .ok_or_else(|| anyhow::anyhow!("vertex {} unplaced", vertex.label()))?;
            let ctx = DataGenContext {
                vertex: vid,
                placement,
                timestep_us: self.config.timestep_us,
                graph: &run_graph,
                placements: mapping.placements.as_map(),
                keys: &mapping.keys,
                iptags: &mapping.iptags,
                reverse_iptags: &mapping.reverse_iptags,
                app_graph: graph_mapping.as_ref().map(|_| &self.app_graph),
                graph_mapping: graph_mapping.as_ref(),
            };
            let regions = vertex.generate_data(&ctx);
            let total: u64 = regions.iter().map(|r| r.data.len() as u64).sum();
            data_bytes.insert(vid, total);
            region_data.insert(vid, regions.into_iter().map(|r| (r.id, r.data)).collect());
        }

        // ---- Figure-9 run-cycle planning --------------------------------
        let plan = plan_run_cycles(
            &machine,
            &run_graph,
            &mapping.placements,
            &data_bytes,
            ticks,
            self.config.recording_slack_bytes,
        )?;

        // ---- loading (§6.3.4) -------------------------------------------
        for (chip, table) in &mapping.tables {
            scamp::load_routing_table(&mut sim, *chip, table.clone())?;
        }
        for tag in mapping.iptags.values() {
            scamp::set_iptag(&mut sim, tag.board, tag.tag, &tag.host, tag.port, tag.strip_sdp)?;
        }
        for rtag in mapping.reverse_iptags.values() {
            scamp::set_reverse_iptag(&mut sim, rtag.board, rtag.port, rtag.destination)?;
        }

        // Bulk data plane (system cores outside the user graph) — set up
        // before app loading so region data can ride the fast data-in
        // streams. A failed install is not swallowed: the reason lands
        // in the provenance report, and loading/extraction fall back to
        // the SCAMP paths.
        let want_plane = self.config.extraction == ExtractionMethod::FastMulticast
            || self.config.loading == LoadMethod::FastMulticast;
        let (fast_path, data_plane_error) = if want_plane {
            let chips: Vec<ChipCoord> = mapping.placements.used_chips().into_iter().collect();
            let placements = mapping.placements.clone();
            let machine_for_picker = machine.clone();
            let mut extra: BTreeMap<ChipCoord, std::collections::BTreeSet<u8>> = BTreeMap::new();
            let picker = move |chip: ChipCoord| -> Option<u8> {
                let used = placements.cores_used_on(chip);
                let taken = extra.entry(chip).or_default();
                let chip_info = machine_for_picker.chip(chip)?;
                for p in chip_info.application_processors().map(|p| p.id) {
                    if !used.contains(&p) && !taken.contains(&p) {
                        taken.insert(p);
                        return Some(p);
                    }
                }
                None // fully packed: this chip falls back to the SCAMP paths
            };
            let opts = DataPlaneOptions {
                port_base: self.config.fast_port,
                extraction: self.config.extraction == ExtractionMethod::FastMulticast,
                data_in: self.config.loading == LoadMethod::FastMulticast,
                threads: self.config.data_plane_threads,
            };
            match FastPath::install(&mut sim, &chips, picker, &opts) {
                Ok(fp) => {
                    // Start the plane's system binaries now — the user
                    // graph is not loaded yet, so only they are Ready —
                    // else the data-in cores could not serve the region
                    // load below (their on_start reads the stream config).
                    scamp::signal_start(&mut sim)?;
                    (Some(fp), None)
                }
                Err(e) => (None, Some(e.to_string())),
            }
        } else {
            (None, None)
        };

        let mut labels = Vec::new();
        // Region loading + binary attach. Fast data-in batches every
        // region into one multi-board streamed load; chips without a
        // writer core take the batched SCAMP fallback.
        let mut fast_reqs: Vec<(ChipCoord, u32, Vec<u8>)> = Vec::new();
        for (vid, vertex) in run_graph.vertices() {
            if vertex.virtual_link().is_some() {
                continue;
            }
            let loc = mapping.placement(vid).unwrap();
            labels.push((vertex.label(), loc));
            let app = self.registry.create(&vertex.binary_name())?;
            let mut recording_sizes = BTreeMap::new();
            if let Some(bytes) = plan.recording_bytes.get(&vid) {
                recording_sizes.insert(0u32, *bytes as u32);
            }
            let regions = region_data.remove(&vid).unwrap_or_default();
            let use_fast = self.config.loading == LoadMethod::FastMulticast
                && fast_path.as_ref().is_some_and(|fp| fp.has_writer(loc.chip()));
            if self.config.loading == LoadMethod::Scamp {
                scamp::load_app_named(
                    &mut sim,
                    loc,
                    &vertex.binary_name(),
                    app,
                    regions,
                    recording_sizes,
                )?;
            } else {
                let mut table = BTreeMap::new();
                for (id, data) in regions {
                    let addr = scamp::alloc_sdram(&mut sim, loc.chip(), data.len() as u32)?;
                    table.insert(id, (addr, data.len() as u32));
                    if use_fast {
                        fast_reqs.push((loc.chip(), addr, data));
                    } else if !data.is_empty() {
                        scamp::write_sdram_batched(&mut sim, loc.chip(), addr, &data)?;
                    }
                }
                scamp::install_app(
                    &mut sim,
                    loc,
                    &vertex.binary_name(),
                    app,
                    table,
                    recording_sizes,
                )?;
            }
        }
        if !fast_reqs.is_empty() {
            let fp = fast_path.as_ref().expect("fast_reqs imply an installed plane");
            let reqs: Vec<(ChipCoord, u32, &[u8])> = fast_reqs
                .iter()
                .map(|(chip, addr, data)| (*chip, *addr, data.as_slice()))
                .collect();
            fp.write_many(&mut sim, &reqs)?;
        }

        // ---- database + notifications (Figure 8) ------------------------
        let database = MappingDatabase::build(&run_graph, &mapping.placements, &mapping.keys);
        self.notifications.database_ready(&database);

        // ---- running (§6.3.5) -------------------------------------------
        scamp::signal_start(&mut sim)?;
        let mut state = RunState {
            sim,
            run_graph,
            graph_mapping,
            mapping,
            plan,
            fast_path,
            data_plane_error,
            recordings: BTreeMap::new(),
            labels,
            ticks_done: 0,
            database,
        };
        let cycles = state.plan.cycles.clone();
        Self::run_cycles(&mut state, &cycles, self.config.extraction)?;
        self.state = Some(state);
        self.check_completion()
    }

    fn resume_run(&mut self, ticks: u64) -> anyhow::Result<()> {
        let extraction = self.config.extraction;
        let state = self.state.as_mut().unwrap();
        // "The minimum time calculated previously is respected" (§6.5).
        let unit = state.plan.steps_per_cycle;
        let mut cycles = Vec::new();
        let mut remaining = ticks;
        while remaining > 0 {
            let c = unit.min(remaining);
            cycles.push(c);
            remaining -= c;
        }
        scamp::signal_resume(&mut state.sim)?;
        Self::run_cycles(state, &cycles, extraction)?;
        self.check_completion()
    }

    /// The Figure-9 loop: run a cycle, drain recordings, flush, resume.
    fn run_cycles(
        state: &mut RunState,
        cycles: &[u64],
        extraction: ExtractionMethod,
    ) -> anyhow::Result<()> {
        for (i, cycle) in cycles.iter().enumerate() {
            if i > 0 {
                scamp::signal_resume(&mut state.sim)?;
            }
            state.sim.start_run_cycle(*cycle);
            state.sim.run_until_idle()?;
            state.ticks_done += cycle;
            Self::extract_recordings(state, extraction)?;
        }
        Ok(())
    }

    fn extract_recordings(
        state: &mut RunState,
        extraction: ExtractionMethod,
    ) -> anyhow::Result<()> {
        let vids: Vec<VertexId> = state.plan.recording_bytes.keys().copied().collect();
        // Split the pending channels between the paths first, so the
        // fast reads batch into one per-board-parallel drain.
        let mut fast: Vec<(VertexId, CoreLocation, u32, usize)> = Vec::new();
        let mut slow: Vec<(VertexId, CoreLocation, u32, usize)> = Vec::new();
        for vid in vids {
            let loc = state.mapping.placement(vid).unwrap();
            let (addr, written, _) = scamp::recording_info(&state.sim, loc, 0)?;
            if written == 0 {
                continue;
            }
            let use_fast = extraction == ExtractionMethod::FastMulticast
                && state
                    .fast_path
                    .as_ref()
                    .is_some_and(|fp| fp.has_reader(loc.chip()));
            if use_fast {
                fast.push((vid, loc, addr, written));
            } else {
                slow.push((vid, loc, addr, written));
            }
        }
        if !fast.is_empty() {
            let reqs: Vec<(ChipCoord, u32, usize)> = fast
                .iter()
                .map(|(_, loc, addr, written)| (loc.chip(), *addr, *written))
                .collect();
            let fp = state.fast_path.as_ref().unwrap();
            let datas = fp.read_many(&mut state.sim, &reqs)?;
            for ((vid, loc, _, _), data) in fast.iter().zip(datas) {
                state
                    .recordings
                    .entry((*vid, 0))
                    .or_default()
                    .extend_from_slice(&data);
                scamp::clear_recording(&mut state.sim, *loc, 0)?;
            }
        }
        for (vid, loc, addr, written) in slow {
            let data = scamp::read_sdram(&mut state.sim, loc.chip(), addr, written)?;
            state
                .recordings
                .entry((vid, 0))
                .or_default()
                .extend_from_slice(&data);
            scamp::clear_recording(&mut state.sim, loc, 0)?;
        }
        Ok(())
    }

    /// §6.3.5 failure detection: error if any core ended in RTE.
    fn check_completion(&mut self) -> anyhow::Result<()> {
        let state = self.state.as_ref().unwrap();
        let bad: Vec<String> = scamp::core_states(&state.sim)
            .into_iter()
            .filter(|(_, s)| *s == CoreState::RunTimeError)
            .map(|(l, _)| l.to_string())
            .collect();
        if !bad.is_empty() {
            let report = self.provenance();
            anyhow::bail!(
                "cores in error state: {bad:?}; anomalies: {:?}",
                report.anomalies
            );
        }
        Ok(())
    }

    // -- results (§6.4) ------------------------------------------------------

    /// Recorded bytes of one machine vertex (channel 0).
    pub fn recording(&self, v: VertexId) -> &[u8] {
        self.state
            .as_ref()
            .and_then(|s| s.recordings.get(&(v, 0)))
            .map(|d| d.as_slice())
            .unwrap_or(&[])
    }

    /// Recordings of every machine vertex an application vertex was
    /// split into, with their atom slices.
    pub fn app_recordings(&self, v: AppVertexId) -> Vec<(Slice, &[u8])> {
        let Some(state) = &self.state else { return Vec::new() };
        let Some(gm) = &state.graph_mapping else { return Vec::new() };
        let Some(mvs) = gm.machine_vertices_of.get(&v) else {
            return Vec::new();
        };
        mvs.iter()
            .map(|(mv, slice)| {
                (
                    *slice,
                    state
                        .recordings
                        .get(&(*mv, 0))
                        .map(|d| d.as_slice())
                        .unwrap_or(&[]),
                )
            })
            .collect()
    }

    /// The machine vertices (and slices) of an application vertex.
    pub fn machine_vertices_of(&self, v: AppVertexId) -> Vec<(VertexId, Slice)> {
        self.state
            .as_ref()
            .and_then(|s| s.graph_mapping.as_ref())
            .and_then(|gm| gm.machine_vertices_of.get(&v).cloned())
            .unwrap_or_default()
    }

    pub fn provenance(&self) -> ProvenanceReport {
        match &self.state {
            Some(state) => {
                let mut report = ProvenanceReport::collect(&state.sim, &state.labels);
                if let Some(e) = &state.data_plane_error {
                    report.anomalies.push(format!(
                        "bulk data plane unavailable (SCAMP fallback in use): {e}"
                    ));
                }
                report
            }
            None => ProvenanceReport::default(),
        }
    }

    pub fn database(&self) -> Option<&MappingDatabase> {
        self.state.as_ref().map(|s| &s.database)
    }

    pub fn mapping(&self) -> Option<&Mapping> {
        self.state.as_ref().map(|s| &s.mapping)
    }

    pub fn machine(&self) -> Option<&Machine> {
        self.state.as_ref().map(|s| &s.sim.machine)
    }

    /// Direct access to the simulated machine (live I/O, tests).
    pub fn sim_mut(&mut self) -> Option<&mut SimMachine> {
        self.state.as_mut().map(|s| &mut s.sim)
    }

    pub fn run_graph(&self) -> Option<&MachineGraph> {
        self.state.as_ref().map(|s| &s.run_graph)
    }

    pub fn ticks_done(&self) -> u64 {
        self.state.as_ref().map(|s| s.ticks_done).unwrap_or(0)
    }

    pub fn runtime(&self) -> Option<&Rc<Runtime>> {
        self.runtime.as_ref()
    }

    // -- closing (§6.6) ------------------------------------------------------

    /// Stop the cores and release the machine; recordings survive until
    /// `reset`, mirroring §6.6's "recorded data will no longer be
    /// available" on the machine itself.
    pub fn stop(&mut self) -> anyhow::Result<()> {
        if let Some(state) = &mut self.state {
            scamp::signal_stop(&mut state.sim)?;
        }
        Ok(())
    }

    /// Forget the run entirely (graphs survive; the next run remaps).
    pub fn reset(&mut self) {
        self.state = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::conway::{ConwayCellVertex, STATE_PARTITION};
    use crate::front::config::MachineSpec;

    /// Build an r x c Conway machine graph.
    fn conway_graph(tools: &mut SpiNNTools, rows: u32, cols: u32, live: &[(u32, u32)]) -> Vec<VertexId> {
        let mut ids = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                let alive = live.contains(&(r, c));
                ids.push(
                    tools
                        .add_machine_vertex(ConwayCellVertex::arc(r, c, alive))
                        .unwrap(),
                );
            }
        }
        let idx = |r: i64, c: i64| -> Option<usize> {
            (r >= 0 && c >= 0 && r < rows as i64 && c < cols as i64)
                .then_some((r * cols as i64 + c) as usize)
        };
        for r in 0..rows as i64 {
            for c in 0..cols as i64 {
                for dr in -1..=1 {
                    for dc in -1..=1 {
                        if (dr, dc) == (0, 0) {
                            continue;
                        }
                        if let Some(n) = idx(r + dr, c + dc) {
                            tools
                                .add_machine_edge(
                                    ids[idx(r, c).unwrap()],
                                    ids[n],
                                    STATE_PARTITION,
                                )
                                .unwrap();
                        }
                    }
                }
            }
        }
        ids
    }

    #[test]
    fn full_flow_conway_blinker() {
        // E3: the complete Figure-8 flow on a real (small) workload.
        let mut tools = SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn3)).unwrap();
        let ids = conway_graph(&mut tools, 5, 5, &[(2, 1), (2, 2), (2, 3)]);
        tools.run_ticks(4).unwrap();
        // Blinker oscillates with period 2: vertical at odd steps.
        let state = |r: u32, c: u32| tools.recording(ids[(r * 5 + c) as usize]);
        assert_eq!(state(2, 2), &[1, 1, 1, 1], "centre always alive");
        assert_eq!(state(2, 1), &[1, 0, 1, 0], "wing flips");
        assert_eq!(state(1, 2), &[0, 1, 0, 1], "vertical wing appears");
        assert_eq!(state(0, 0), &[0, 0, 0, 0], "corner stays dead");
        // no dropped packets on this tiny graph
        assert_eq!(tools.provenance().total_dropped(), 0);
    }

    #[test]
    fn resume_continues_the_oscillation() {
        // E3/§6.5: run, return control, resume without remapping.
        let mut tools = SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn3)).unwrap();
        let ids = conway_graph(&mut tools, 5, 5, &[(2, 1), (2, 2), (2, 3)]);
        tools.run_ticks(2).unwrap();
        assert_eq!(tools.ticks_done(), 2);
        tools.run_ticks(2).unwrap();
        assert_eq!(tools.ticks_done(), 4);
        let wing = tools.recording(ids[(2 * 5 + 1) as usize]);
        assert_eq!(wing, &[1, 0, 1, 0]);
    }

    #[test]
    fn mapping_threads_do_not_change_results() {
        let run = |threads: usize| -> Vec<u8> {
            let mut tools = SpiNNTools::new(
                ToolsConfig::new(MachineSpec::Spinn3).with_mapping_threads(threads),
            )
            .unwrap();
            let ids = conway_graph(&mut tools, 5, 5, &[(2, 1), (2, 2), (2, 3)]);
            assert_eq!(tools.mapping_threads(), threads);
            tools.run_ticks(4).unwrap();
            tools.recording(ids[(2 * 5 + 1) as usize]).to_vec()
        };
        let serial = run(1);
        assert_eq!(serial, run(4), "threaded mapping changed the simulation");
        assert_eq!(serial, &[1, 0, 1, 0]);
    }

    #[test]
    fn mapping_threads_locked_once_running() {
        let mut tools = SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn3)).unwrap();
        tools.set_mapping_threads(2).unwrap();
        conway_graph(&mut tools, 3, 3, &[]);
        tools.run_ticks(1).unwrap();
        assert!(tools.set_mapping_threads(4).is_err());
        tools.reset();
        assert!(tools.set_mapping_threads(4).is_ok());
    }

    #[test]
    fn graph_changes_after_run_rejected() {
        let mut tools = SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn3)).unwrap();
        conway_graph(&mut tools, 3, 3, &[]);
        tools.run_ticks(1).unwrap();
        assert!(tools
            .add_machine_vertex(ConwayCellVertex::arc(9, 9, false))
            .is_err());
        tools.reset();
        assert!(tools
            .add_machine_vertex(ConwayCellVertex::arc(9, 9, false))
            .is_ok());
    }

    #[test]
    fn database_contains_placements_and_keys() {
        let mut tools = SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn3)).unwrap();
        conway_graph(&mut tools, 3, 3, &[(1, 1)]);
        tools.run_ticks(1).unwrap();
        let db = tools.database().unwrap();
        assert!(db.placement_of("cell_0_0").is_some());
        assert!(db.key_of("cell_1_1", STATE_PARTITION).is_some());
    }

    #[test]
    fn mixing_graphs_is_an_error() {
        let mut tools = SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn3)).unwrap();
        tools
            .add_machine_vertex(ConwayCellVertex::arc(0, 0, true))
            .unwrap();
        tools
            .add_application_vertex(crate::apps::poisson::PoissonSourceVertex::arc(
                "p", 10, 5.0, 1, false,
            ))
            .unwrap();
        assert!(tools.run_ticks(1).is_err());
    }

    #[test]
    fn fast_data_plane_loading_matches_scamp_loading() {
        // E12 correctness half: the same workload, loaded over the
        // data-in streams and extracted over per-board readers, produces
        // byte-identical recordings to the pure-SCAMP flow.
        // 3x3 leaves room on the Ethernet chip for all four plane cores.
        let run = |config: ToolsConfig| -> Vec<Vec<u8>> {
            let mut tools = SpiNNTools::new(config).unwrap();
            let ids = conway_graph(&mut tools, 3, 3, &[(1, 0), (1, 1), (1, 2)]);
            tools.run_ticks(4).unwrap();
            ids.iter().map(|v| tools.recording(*v).to_vec()).collect()
        };
        let scamp = run(ToolsConfig::new(MachineSpec::Spinn3));
        let fast = run(ToolsConfig::new(MachineSpec::Spinn3)
            .with_loading(LoadMethod::FastMulticast)
            .with_extraction(ExtractionMethod::FastMulticast));
        let batched =
            run(ToolsConfig::new(MachineSpec::Spinn3).with_loading(LoadMethod::ScampBatched));
        assert_eq!(scamp, fast, "data plane changed the simulation");
        assert_eq!(scamp, batched, "batched loading changed the simulation");
    }

    #[test]
    fn failed_plane_install_lands_in_provenance() {
        // Pack every application core so the plane has nowhere to live:
        // the run must still succeed over SCAMP, and the report must say
        // why the fast path is absent (no silent `.ok()` fallback).
        let mut tools = SpiNNTools::new(
            ToolsConfig::new(MachineSpec::Spinn3).with_extraction(ExtractionMethod::FastMulticast),
        )
        .unwrap();
        let ids = conway_graph(&mut tools, 4, 17, &[(1, 5)]);
        assert_eq!(ids.len(), 68, "exactly the machine's application cores");
        tools.run_ticks(2).unwrap();
        let report = tools.provenance();
        assert!(
            report
                .anomalies
                .iter()
                .any(|a| a.contains("bulk data plane unavailable")),
            "anomalies: {:?}",
            report.anomalies
        );
    }

    #[test]
    fn too_big_graph_rejected_at_discovery() {
        let mut tools = SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn3)).unwrap();
        for i in 0..100 {
            tools
                .add_machine_vertex(ConwayCellVertex::arc(i, 0, false))
                .unwrap();
        }
        let err = tools.run_ticks(1).unwrap_err().to_string();
        assert!(err.contains("cores"), "{err}");
    }
}
