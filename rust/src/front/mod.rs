//! The SpiNNTools front end: the user-facing flow of Figure 8.
//!
//! [`SpiNNTools`] ties everything together: setup → graph creation →
//! machine discovery → mapping (run on the Figure-10 algorithm engine)
//! → data generation → loading → running in Figure-9 buffer cycles →
//! extraction of results and provenance → resume/reset → close.

mod allocator;
mod buffer;
pub mod bus;
mod checkpoint;
mod config;
mod extraction;
pub mod fabric_probe;
mod live;
mod provenance;
mod service;
mod tools;

pub use allocator::BoardAllocator;
pub use buffer::{plan_run_cycles, RunCyclePlan};
pub use checkpoint::{
    CheckpointConfig, Checkpointer, FileCheckpointer, MemoryCheckpointer, RunSnapshot,
};
pub use config::{
    BootFaults, ExtractionMethod, HealPolicy, LoadMethod, MachineSpec, SupervisorConfig,
    ToolsConfig,
};
pub use bus::{CallbackSink, EventBus, JsonlSink, Metrics, RingSink, RunEvent, Sink, SinkId};
pub use extraction::{DataPlaneOptions, FastPath, WriteStats};
pub use live::{LifecycleEvent, LifecycleLog, LiveEvent, LiveEventListener, LiveInjector, LiveSource};
pub use provenance::{
    HealReport, ProvenanceReport, RemapReport, ServiceReport, TenantReport, VertexProvenance,
};
pub use service::MachineService;
pub use tools::SpiNNTools;
