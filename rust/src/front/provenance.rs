//! Provenance extraction and anomaly analysis (§6.3.5).

use std::collections::BTreeMap;

use crate::machine::{ChipCoord, CoreLocation};
use crate::simulator::{scamp, CoreState, RouterStats, SimMachine, WireStats};

/// One core's provenance.
#[derive(Debug, Clone)]
pub struct VertexProvenance {
    pub label: String,
    pub placement: CoreLocation,
    pub state: CoreState,
    pub counters: BTreeMap<String, u64>,
}

/// What the last (re-)mapping pass did (DESIGN.md §7): which pipeline
/// stages actually ran vs. were served from the fingerprint cache, and
/// how much of the machine state had to be rewritten. A full first map
/// reports `stages_cached == 0`; a small incremental delta reports
/// `stages_rerun` strictly below the stage count.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RemapReport {
    /// Pipeline stages that executed this pass.
    pub stages_rerun: usize,
    /// Pipeline stages skipped via the fingerprint stage cache.
    pub stages_cached: usize,
    /// Vertices whose binary/regions were (re)loaded — new vertices
    /// plus existing ones whose region bytes changed.
    pub vertices_replaced: usize,
    /// Chips whose routing tables were reinstalled.
    pub tables_rewritten: usize,
    /// Per-stage (name, cached, elapsed µs), in execution order.
    pub stages: Vec<(String, bool, u64)>,
}

impl RemapReport {
    /// Build a report from one pipeline pass's stage stats plus the
    /// front end's load/install counters (shared by the first-run and
    /// reconcile paths so the two can never drift).
    pub fn from_stages(
        stages: &[crate::algorithms::StageStat],
        vertices_replaced: usize,
        tables_rewritten: usize,
    ) -> Self {
        Self {
            stages_rerun: stages.iter().filter(|s| !s.cached).count(),
            stages_cached: stages.iter().filter(|s| s.cached).count(),
            vertices_replaced,
            tables_rewritten,
            stages: stages
                .iter()
                .map(|s| (s.name.clone(), s.cached, s.elapsed_us))
                .collect(),
        }
    }

    /// Total pipeline stages this pass considered.
    pub fn stage_count(&self) -> usize {
        self.stages_rerun + self.stages_cached
    }
}

/// One self-healing pass (DESIGN.md §8): what failed, what the heal
/// moved, and what it cost. Recorded by the run supervisor every time
/// [`crate::front::config::HealPolicy::Remap`] repairs a run.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct HealReport {
    /// Human-readable descriptions of the faults this heal repaired
    /// (classification + location + any IOBUF excerpt).
    pub faults: Vec<String>,
    /// Vertices whose placement changed (displaced off dead resources).
    pub vertices_moved: usize,
    /// Chips whose routing tables were reinstalled.
    pub tables_rewritten: usize,
    /// Host wall-clock of the mapping pass alone, µs.
    pub map_elapsed_us: u64,
    /// Host wall-clock of the whole heal (re-discovery, re-map, reload,
    /// restart), µs.
    pub heal_elapsed_us: u64,
    /// Pipeline stages served from the fingerprint cache during the
    /// heal's re-map (the reason heal-time beats a full re-map).
    pub stages_cached: usize,
    pub stages_rerun: usize,
    /// The snapshot tick this heal restored from (DESIGN.md §9): the
    /// restart replayed only `total - restored_from_tick` ticks.
    /// `None` when checkpointing is off — the restart replayed the
    /// whole history from tick 0.
    pub restored_from_tick: Option<u64>,
    /// Host-link transport counters at the moment of the heal: how many
    /// timeouts/retries/escalations the reliable wire layer absorbed
    /// before (and while) this failure was repaired.
    pub wire: WireStats,
}

/// The whole-run provenance report.
#[derive(Debug, Clone, Default)]
pub struct ProvenanceReport {
    pub vertices: Vec<VertexProvenance>,
    pub routers: BTreeMap<ChipCoord, RouterStats>,
    /// Human-readable anomalies ("error/warning lines", §6.3.5).
    pub anomalies: Vec<String>,
    /// What the most recent mapping pass re-ran vs. reused (§6.5 /
    /// DESIGN.md §7); `None` before the first run.
    pub remap: Option<RemapReport>,
    /// Every self-healing pass of the current run state, in order.
    pub heals: Vec<HealReport>,
    /// Host-link transport counters for the whole run: a lossless wire
    /// reports all-zero; retries/timeouts/escalations quantify what the
    /// reliable transport absorbed.
    pub wire: WireStats,
}

impl ProvenanceReport {
    /// Collect provenance for the given placements and analyse it.
    pub fn collect(
        sim: &SimMachine,
        placements: &[(String, CoreLocation)],
    ) -> ProvenanceReport {
        let mut report = ProvenanceReport::default();
        for (label, loc) in placements {
            let state = scamp::core_state(sim, *loc).unwrap_or(CoreState::Idle);
            let counters = scamp::provenance(sim, *loc).unwrap_or_default();
            if state == CoreState::RunTimeError {
                report
                    .anomalies
                    .push(format!("core {loc} ({label}) hit a runtime error"));
            }
            if state == CoreState::Watchdog {
                report
                    .anomalies
                    .push(format!("core {loc} ({label}) stalled (watchdog fired)"));
            }
            for (k, v) in &counters {
                if k.starts_with("rte:") {
                    report.anomalies.push(format!("{label}: {k}"));
                }
                if k == "recording_overflow" {
                    report
                        .anomalies
                        .push(format!("{label}: lost recordings x{v} (buffer full)"));
                }
                if k == "spikes_unmatched" {
                    report
                        .anomalies
                        .push(format!("{label}: {v} packets matched no synapse block"));
                }
                if k == "missed_neighbour_states" {
                    report
                        .anomalies
                        .push(format!("{label}: {v} phases saw missing neighbour states"));
                }
            }
            report.vertices.push(VertexProvenance {
                label: label.clone(),
                placement: *loc,
                state,
                counters,
            });
        }
        for chip in sim.machine.chip_coords().collect::<Vec<_>>() {
            // A scoped (multi-tenant) session reports only its own
            // partition's routers: another tenant's drops are not this
            // run's anomalies.
            if !sim.in_scope(chip) {
                continue;
            }
            if let Some(stats) = sim.router_stats(chip) {
                if stats.mc_dropped > 0 {
                    report.anomalies.push(format!(
                        "router {chip:?}: {} dropped packets ({} unrecoverable)",
                        stats.mc_dropped, stats.mc_lost_forever
                    ));
                }
                report.routers.insert(chip, stats);
            }
        }
        report.wire = sim.wire_stats();
        if report.wire.escalations > 0 {
            report.anomalies.push(format!(
                "host link escalations: {} board(s) went silent past the SCP retry budget",
                report.wire.escalations
            ));
        }
        if report.wire.unknown_live_keys > 0 {
            report.anomalies.push(format!(
                "live output: {} multicast key(s) not in the mapping database (stale \
                 routing entry or foreign traffic?)",
                report.wire.unknown_live_keys
            ));
        }
        report
    }

    pub fn total_dropped(&self) -> u64 {
        self.routers.values().map(|r| r.mc_dropped).sum()
    }

    pub fn total_reinjected(&self) -> u64 {
        self.routers.values().map(|r| r.mc_reinjected).sum()
    }

    /// Sum one named counter over all vertices.
    pub fn counter_total(&self, name: &str) -> u64 {
        self.vertices
            .iter()
            .filter_map(|v| v.counters.get(name))
            .sum()
    }
}

/// One tenant's slice of a [`ServiceReport`] (DESIGN.md §11): where the
/// job ran, which key window its multicast traffic was confined to, and
/// what the tenancy cost it.
#[derive(Debug, Clone, Default)]
pub struct TenantReport {
    pub name: String,
    /// Ethernet chips of the boards the tenant finished on.
    pub boards: Vec<ChipCoord>,
    /// The `[base, limit)` multicast key window the session allocated
    /// inside — pairwise disjoint across tenants by construction.
    pub key_space: (u64, u64),
    /// Final placements (label, core), all inside the partition.
    pub placements: Vec<(String, CoreLocation)>,
    /// Self-healing passes that ran inside this tenant's partition.
    pub heals: usize,
    /// Times the tenant was suspended and moved to a fresh partition.
    pub evictions: usize,
    /// Scheduler rounds spent queued before (first) admission.
    pub queue_rounds: u64,
    /// Simulated ticks the job completed.
    pub ticks_done: u64,
}

/// What the multi-tenant machine service did with its machine: one
/// entry per job, plus the pool-level accounting. Attached to the
/// service's provenance the way [`HealReport`]s attach to a run's.
#[derive(Debug, Clone, Default)]
pub struct ServiceReport {
    pub tenants: Vec<TenantReport>,
    /// Boards in the machine when the service opened.
    pub boards_total: usize,
    /// Boards retired after dying under a tenant.
    pub boards_retired: usize,
    /// Scheduler rounds the service ran.
    pub rounds: u64,
}

impl ServiceReport {
    /// Sanity invariant used by the tenant property suite: no two
    /// tenants' key windows overlap.
    pub fn key_windows_disjoint(&self) -> bool {
        for (i, a) in self.tenants.iter().enumerate() {
            for b in self.tenants.iter().skip(i + 1) {
                if a.key_space.0 < b.key_space.1 && b.key_space.0 < a.key_space.1 {
                    return false;
                }
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::MachineBuilder;
    use crate::simulator::{CoreApp, CoreCtx, SimConfig};

    struct Noisy;
    impl CoreApp for Noisy {
        fn on_timer(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
            ctx.count("recording_overflow", 1);
            Ok(())
        }
    }

    #[test]
    fn anomalies_surface_overflows() {
        let m = MachineBuilder::spinn3().build();
        let mut sim = SimMachine::boot(m, SimConfig::default());
        let loc = CoreLocation::new(0, 0, 1);
        scamp::load_app(&mut sim, loc, Box::new(Noisy), Default::default(), Default::default())
            .unwrap();
        scamp::signal_start(&mut sim).unwrap();
        sim.start_run_cycle(3);
        sim.run_until_idle().unwrap();
        let report = ProvenanceReport::collect(&sim, &[("noisy".into(), loc)]);
        assert!(report
            .anomalies
            .iter()
            .any(|a| a.contains("lost recordings")));
        assert_eq!(report.counter_total("recording_overflow"), 3);
    }
}
