//! Provenance extraction and anomaly analysis (§6.3.5).

use std::collections::BTreeMap;

use crate::machine::{ChipCoord, CoreLocation};
use crate::simulator::{scamp, CoreState, RouterStats, SimMachine};

/// One core's provenance.
#[derive(Debug, Clone)]
pub struct VertexProvenance {
    pub label: String,
    pub placement: CoreLocation,
    pub state: CoreState,
    pub counters: BTreeMap<String, u64>,
}

/// The whole-run provenance report.
#[derive(Debug, Clone, Default)]
pub struct ProvenanceReport {
    pub vertices: Vec<VertexProvenance>,
    pub routers: BTreeMap<ChipCoord, RouterStats>,
    /// Human-readable anomalies ("error/warning lines", §6.3.5).
    pub anomalies: Vec<String>,
}

impl ProvenanceReport {
    /// Collect provenance for the given placements and analyse it.
    pub fn collect(
        sim: &SimMachine,
        placements: &[(String, CoreLocation)],
    ) -> ProvenanceReport {
        let mut report = ProvenanceReport::default();
        for (label, loc) in placements {
            let state = scamp::core_state(sim, *loc).unwrap_or(CoreState::Idle);
            let counters = scamp::provenance(sim, *loc).unwrap_or_default();
            if state == CoreState::RunTimeError {
                report
                    .anomalies
                    .push(format!("core {loc} ({label}) hit a runtime error"));
            }
            for (k, v) in &counters {
                if k.starts_with("rte:") {
                    report.anomalies.push(format!("{label}: {k}"));
                }
                if k == "recording_overflow" {
                    report
                        .anomalies
                        .push(format!("{label}: lost recordings x{v} (buffer full)"));
                }
                if k == "spikes_unmatched" {
                    report
                        .anomalies
                        .push(format!("{label}: {v} packets matched no synapse block"));
                }
                if k == "missed_neighbour_states" {
                    report
                        .anomalies
                        .push(format!("{label}: {v} phases saw missing neighbour states"));
                }
            }
            report.vertices.push(VertexProvenance {
                label: label.clone(),
                placement: *loc,
                state,
                counters,
            });
        }
        for chip in sim.machine.chip_coords().collect::<Vec<_>>() {
            if let Some(stats) = sim.router_stats(chip) {
                if stats.mc_dropped > 0 {
                    report.anomalies.push(format!(
                        "router {chip:?}: {} dropped packets ({} unrecoverable)",
                        stats.mc_dropped, stats.mc_lost_forever
                    ));
                }
                report.routers.insert(chip, stats);
            }
        }
        report
    }

    pub fn total_dropped(&self) -> u64 {
        self.routers.values().map(|r| r.mc_dropped).sum()
    }

    pub fn total_reinjected(&self) -> u64 {
        self.routers.values().map(|r| r.mc_reinjected).sum()
    }

    /// Sum one named counter over all vertices.
    pub fn counter_total(&self, name: &str) -> u64 {
        self.vertices
            .iter()
            .filter_map(|v| v.counters.get(name))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::MachineBuilder;
    use crate::simulator::{CoreApp, CoreCtx, SimConfig};

    struct Noisy;
    impl CoreApp for Noisy {
        fn on_timer(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
            ctx.count("recording_overflow", 1);
            Ok(())
        }
    }

    #[test]
    fn anomalies_surface_overflows() {
        let m = MachineBuilder::spinn3().build();
        let mut sim = SimMachine::boot(m, SimConfig::default());
        let loc = CoreLocation::new(0, 0, 1);
        scamp::load_app(&mut sim, loc, Box::new(Noisy), Default::default(), Default::default())
            .unwrap();
        scamp::signal_start(&mut sim).unwrap();
        sim.start_run_cycle(3);
        sim.run_until_idle().unwrap();
        let report = ProvenanceReport::collect(&sim, &[("noisy".into(), loc)]);
        assert!(report
            .anomalies
            .iter()
            .any(|a| a.contains("lost recordings")));
        assert_eq!(report.counter_total("recording_overflow"), 3);
    }
}
