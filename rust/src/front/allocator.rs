//! Board-aligned partitioning of one machine among tenants (DESIGN.md
//! §11). SpiNNaker machines are built from 48-chip boards, each with
//! its own Ethernet chip and host link, so the board is the natural
//! isolation unit: giving a tenant whole boards gives it private IP-tag
//! slots, a private host link, and a chip set no other tenant's
//! placements or routes can touch.
//!
//! The allocator groups the machine's chips by their `nearest_ethernet`
//! (the board identity SCAMP itself uses), derives board adjacency from
//! the cross-board chip links, and hands out *connected* sets of free
//! boards first-fit in deterministic board order. Freed boards return
//! to the pool; boards that died under a tenant are retired for the
//! lifetime of the service.

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use crate::machine::{ChipCoord, Machine, ALL_DIRECTIONS};

/// Carves one machine into board-aligned partitions.
pub struct BoardAllocator {
    /// Board (Ethernet chip) -> the chips on that board.
    boards: BTreeMap<ChipCoord, BTreeSet<ChipCoord>>,
    /// Board -> boards reachable over at least one cross-board link.
    adjacency: BTreeMap<ChipCoord, BTreeSet<ChipCoord>>,
    /// Boards available for allocation.
    free: BTreeSet<ChipCoord>,
    /// Boards permanently removed from service (died under a tenant).
    retired: BTreeSet<ChipCoord>,
}

impl BoardAllocator {
    pub fn new(machine: &Machine) -> Self {
        let mut boards: BTreeMap<ChipCoord, BTreeSet<ChipCoord>> = BTreeMap::new();
        for c in machine.chip_coords() {
            if let Some(eth) = machine.nearest_ethernet(c) {
                boards.entry(eth).or_default().insert(c);
            }
        }
        let board_of: BTreeMap<ChipCoord, ChipCoord> = boards
            .iter()
            .flat_map(|(eth, chips)| chips.iter().map(|c| (*c, *eth)))
            .collect();
        let mut adjacency: BTreeMap<ChipCoord, BTreeSet<ChipCoord>> = BTreeMap::new();
        for (c, eth) in &board_of {
            for d in ALL_DIRECTIONS {
                if let Some(to) = machine.link_target(*c, d) {
                    if let Some(other) = board_of.get(&to) {
                        if other != eth {
                            adjacency.entry(*eth).or_default().insert(*other);
                            adjacency.entry(*other).or_default().insert(*eth);
                        }
                    }
                }
            }
        }
        let free = boards.keys().copied().collect();
        Self { boards, adjacency, free, retired: BTreeSet::new() }
    }

    /// Total number of boards in the machine.
    pub fn n_boards(&self) -> usize {
        self.boards.len()
    }

    /// Boards currently free to allocate.
    pub fn n_free(&self) -> usize {
        self.free.len()
    }

    /// Boards retired after dying under a tenant.
    pub fn n_retired(&self) -> usize {
        self.retired.len()
    }

    /// Allocate `n` free boards forming a connected set (so a tenant's
    /// placements can always route inside its own partition), first-fit
    /// from the lowest free board: a breadth-first growth from each
    /// candidate seed in deterministic order. Returns `None` when no
    /// connected set of `n` free boards exists right now — the caller
    /// queues and retries after a free.
    pub fn allocate(&mut self, n: usize) -> Option<Vec<ChipCoord>> {
        if n == 0 || n > self.free.len() {
            return None;
        }
        for seed in self.free.iter().copied().collect::<Vec<_>>() {
            let mut taken: BTreeSet<ChipCoord> = BTreeSet::new();
            let mut queue = VecDeque::from([seed]);
            while let Some(b) = queue.pop_front() {
                if taken.len() >= n {
                    break;
                }
                if !taken.insert(b) {
                    continue;
                }
                if let Some(next) = self.adjacency.get(&b) {
                    // Deterministic: BTreeSet iteration is ordered.
                    for nb in next {
                        if self.free.contains(nb) && !taken.contains(nb) {
                            queue.push_back(*nb);
                        }
                    }
                }
            }
            if taken.len() == n {
                for b in &taken {
                    self.free.remove(b);
                }
                return Some(taken.into_iter().collect());
            }
        }
        None
    }

    /// Return a tenant's boards to the pool. Boards in `dead` (their
    /// host link or chips died under the tenant) are retired instead of
    /// freed — nothing sound can be loaded onto them again.
    pub fn free(&mut self, boards: &[ChipCoord], dead: &BTreeSet<ChipCoord>) {
        for b in boards {
            if dead.contains(b) {
                self.retired.insert(*b);
            } else if self.boards.contains_key(b) {
                self.free.insert(*b);
            }
        }
    }

    /// Every chip of the given boards (a tenant's scope).
    pub fn chips_of(&self, boards: &[ChipCoord]) -> BTreeSet<ChipCoord> {
        boards
            .iter()
            .filter_map(|b| self.boards.get(b))
            .flatten()
            .copied()
            .collect()
    }

    /// Every chip *not* on the given boards (a tenant's forbidden set —
    /// including retired boards' chips, which stay forbidden forever).
    pub fn chips_outside(&self, boards: &[ChipCoord]) -> BTreeSet<ChipCoord> {
        let own: BTreeSet<ChipCoord> = boards.iter().copied().collect();
        self.boards
            .iter()
            .filter(|(eth, _)| !own.contains(eth))
            .flat_map(|(_, chips)| chips.iter().copied())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::front::config::MachineSpec;
    use crate::front::config::ToolsConfig;

    fn machine(spec: MachineSpec) -> Machine {
        ToolsConfig::new(spec).machine_builder().build()
    }

    #[test]
    fn groups_boards_and_allocates_connected_sets() {
        let m = machine(MachineSpec::Boards(12));
        let mut alloc = BoardAllocator::new(&m);
        assert_eq!(alloc.n_boards(), 12);
        assert_eq!(m.n_chips(), 576);

        let a = alloc.allocate(3).expect("3 connected boards");
        assert_eq!(a.len(), 3);
        assert_eq!(alloc.n_free(), 9);
        // Connected: every board reaches every other within the set.
        let set: BTreeSet<ChipCoord> = a.iter().copied().collect();
        let mut seen = BTreeSet::new();
        let mut queue = VecDeque::from([a[0]]);
        while let Some(b) = queue.pop_front() {
            if !seen.insert(b) {
                continue;
            }
            for nb in alloc.adjacency.get(&b).into_iter().flatten() {
                if set.contains(nb) {
                    queue.push_back(*nb);
                }
            }
        }
        assert_eq!(seen, set, "allocated boards are not connected");
        // Scope and forbidden partition the machine's chips exactly.
        let scope = alloc.chips_of(&a);
        let outside = alloc.chips_outside(&a);
        assert_eq!(scope.len() + outside.len(), m.n_chips());
        assert!(scope.is_disjoint(&outside));
        assert_eq!(scope.len(), 3 * 48);
    }

    #[test]
    fn free_returns_boards_and_retires_dead_ones() {
        let m = machine(MachineSpec::Boards(12));
        let mut alloc = BoardAllocator::new(&m);
        let a = alloc.allocate(2).unwrap();
        let b = alloc.allocate(2).unwrap();
        assert_eq!(alloc.n_free(), 8);
        // Two tenants never share a board.
        assert!(a.iter().all(|x| !b.contains(x)));

        let dead: BTreeSet<ChipCoord> = [a[0]].into_iter().collect();
        alloc.free(&a, &dead);
        assert_eq!(alloc.n_free(), 9, "one board retired, one freed");
        assert_eq!(alloc.n_retired(), 1);
        // The retired board can never be allocated again.
        let mut grabbed = Vec::new();
        while let Some(more) = alloc.allocate(1) {
            grabbed.extend(more);
        }
        assert!(!grabbed.contains(&a[0]));
        assert_eq!(grabbed.len(), 9);
    }

    #[test]
    fn refuses_oversized_requests() {
        let m = machine(MachineSpec::Spinn5);
        let mut alloc = BoardAllocator::new(&m);
        assert_eq!(alloc.n_boards(), 1);
        assert!(alloc.allocate(2).is_none());
        assert!(alloc.allocate(0).is_none());
        let one = alloc.allocate(1).unwrap();
        assert_eq!(alloc.chips_of(&one).len(), 48);
    }
}
