//! The unified run-event bus (§6.9 live interaction, DESIGN.md §13).
//!
//! Every surface the front end already produces — LPG live data,
//! tenant lifecycle, heal/chaos/fault findings, checkpoint captures,
//! provenance anomalies — plus a periodic [`Metrics`] sample, flows
//! through one typed [`RunEvent`] stream that external consumers
//! subscribe to *while the run is going*, via pluggable [`Sink`]s.
//!
//! The contract, pinned by `tests/bus.rs`:
//!
//! - **Observation-only.** Attaching sinks never changes what the run
//!   computes: no simulated time is spent, no draws are made, and run
//!   digests are byte-identical with 0 or N sinks attached.
//! - **Never blocks, never reorders.** Each sink owns a bounded buffer
//!   with a sequence cursor; a sink that refuses delivery keeps its
//!   backlog in order, and once the buffer fills, *new* events are
//!   dropped and counted (`dropped`) rather than stalling the run or
//!   delivering out of order. Delivered sequence numbers are strictly
//!   increasing per sink.
//! - **Subscribable mid-run.** [`EventBus::attach`] works at any point;
//!   a late sink simply starts at the current sequence number.
//! - **Re-entrant.** Sinks are invoked with the hub unborrowed, so a
//!   sink may call back into the same bus (emit, attach, detach);
//!   re-entrant emissions queue behind the event being delivered.

use std::cell::RefCell;
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::io::Write as _;
use std::rc::Rc;

use crate::util::json::Json;

use super::live::{LifecycleEvent, LiveEvent};

/// Default per-sink buffer depth for [`EventBus::attach`] (deep enough
/// that a well-behaved sink never drops; `attach_buffered` sizes it
/// explicitly for backpressure tests and tiny consumers).
pub const DEFAULT_SINK_CAPACITY: usize = 4096;

/// One event on the bus. Everything an operator can watch a run do,
/// as one typed stream (the taxonomy of DESIGN.md §13).
#[derive(Debug, Clone, PartialEq)]
pub enum RunEvent {
    /// A run segment started: `ticks` simulated ticks from `from_tick`.
    RunStarted { from_tick: u64, ticks: u64 },
    /// The run segment completed; the session now stands at `ticks_done`.
    RunCompleted { ticks_done: u64 },
    /// Decoded (or undecodable) LPG live output — the §6.9 spike channel.
    Live(LiveEvent),
    /// Multi-tenant lifecycle (submission/admission/eviction/...),
    /// mirrored from the service's [`super::LifecycleLog`].
    Lifecycle(LifecycleEvent),
    /// The chaos plan injected a fault into the fabric at `at_tick`.
    ChaosInjected { at_tick: u64, fault: String },
    /// The run supervisor classified a failure (a heal or abort follows).
    Fault { description: String },
    /// A self-healing pass completed (mirrors the pushed `HealReport`).
    Healed {
        faults: usize,
        vertices_moved: usize,
        restored_from_tick: Option<u64>,
        heal_elapsed_us: u64,
    },
    /// A graph mutation was reconciled into the loaded machine.
    Reconciled { stages_rerun: usize, stages_cached: usize },
    /// A checkpoint snapshot was captured at `tick`.
    CheckpointCaptured { tick: u64 },
    /// A provenance anomaly line, mirrored once per distinct text.
    Anomaly { text: String },
    /// Periodic run telemetry (see [`Metrics`]).
    Metrics(Metrics),
}

/// Periodic run telemetry: sampled at supervisor-poll/checkpoint chunk
/// boundaries by the run driver, and once per quantum (with the tenant
/// name and quantum latency) by the machine service. Rates are wall
/// clock, so they are *not* deterministic — they ride the bus only and
/// never feed back into the run.
#[derive(Debug, Clone, PartialEq)]
pub struct Metrics {
    /// Absolute simulated tick of the sample.
    pub tick: u64,
    /// Simulated nanoseconds of the sample.
    pub sim_ns: u64,
    /// Simulated ticks per wall-clock second over the sample window.
    pub ticks_per_sec: f64,
    /// Multicast packets routed per wall-clock second over the window
    /// (from the aggregate [`crate::simulator::RouterStats`]).
    pub packets_per_sec: f64,
    /// Multicast packets routed during the window.
    pub packets: u64,
    /// Cumulative wire retries (SCP retransmits + empty bulk rounds).
    pub wire_retries: u64,
    /// The tenant the sample concerns (service quanta only).
    pub tenant: Option<String>,
    /// Wall-clock latency of the tenant's last quantum, µs (service
    /// quanta only).
    pub quantum_latency_us: Option<u64>,
}

impl RunEvent {
    /// Short stable tag for filtering/JSONL (`"metrics"`, `"live"`, ...).
    pub fn kind(&self) -> &'static str {
        match self {
            RunEvent::RunStarted { .. } => "run_started",
            RunEvent::RunCompleted { .. } => "run_completed",
            RunEvent::Live(_) => "live",
            RunEvent::Lifecycle(_) => "lifecycle",
            RunEvent::ChaosInjected { .. } => "chaos_injected",
            RunEvent::Fault { .. } => "fault",
            RunEvent::Healed { .. } => "healed",
            RunEvent::Reconciled { .. } => "reconciled",
            RunEvent::CheckpointCaptured { .. } => "checkpoint",
            RunEvent::Anomaly { .. } => "anomaly",
            RunEvent::Metrics(_) => "metrics",
        }
    }

    /// The event as a JSON object (JSONL sink, dashboards).
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("type".into(), Json::from(self.kind()));
        match self {
            RunEvent::RunStarted { from_tick, ticks } => {
                o.insert("from_tick".into(), num(*from_tick));
                o.insert("ticks".into(), num(*ticks));
            }
            RunEvent::RunCompleted { ticks_done } => {
                o.insert("ticks_done".into(), num(*ticks_done));
            }
            RunEvent::Live(e) => {
                if e.is_decoded() {
                    o.insert("vertex".into(), Json::from(e.vertex()));
                    o.insert("partition".into(), Json::from(e.partition()));
                    o.insert("atom".into(), opt_num(e.atom()));
                } else {
                    o.insert("raw_key".into(), opt_num(e.raw_key()));
                }
                o.insert("payload".into(), opt_num(e.payload));
            }
            RunEvent::Lifecycle(e) => {
                o.insert("tenant".into(), Json::from(e.tenant()));
                o.insert("event".into(), Json::Str(format!("{e:?}")));
            }
            RunEvent::ChaosInjected { at_tick, fault } => {
                o.insert("at_tick".into(), num(*at_tick));
                o.insert("fault".into(), Json::Str(fault.clone()));
            }
            RunEvent::Fault { description } => {
                o.insert("description".into(), Json::Str(description.clone()));
            }
            RunEvent::Healed {
                faults,
                vertices_moved,
                restored_from_tick,
                heal_elapsed_us,
            } => {
                o.insert("faults".into(), Json::from(*faults));
                o.insert("vertices_moved".into(), Json::from(*vertices_moved));
                o.insert("restored_from_tick".into(), opt_num64(*restored_from_tick));
                o.insert("heal_elapsed_us".into(), num(*heal_elapsed_us));
            }
            RunEvent::Reconciled { stages_rerun, stages_cached } => {
                o.insert("stages_rerun".into(), Json::from(*stages_rerun));
                o.insert("stages_cached".into(), Json::from(*stages_cached));
            }
            RunEvent::CheckpointCaptured { tick } => {
                o.insert("tick".into(), num(*tick));
            }
            RunEvent::Anomaly { text } => {
                o.insert("text".into(), Json::Str(text.clone()));
            }
            RunEvent::Metrics(m) => {
                o.insert("tick".into(), num(m.tick));
                o.insert("sim_ns".into(), num(m.sim_ns));
                o.insert("ticks_per_sec".into(), Json::Num(m.ticks_per_sec));
                o.insert("packets_per_sec".into(), Json::Num(m.packets_per_sec));
                o.insert("packets".into(), num(m.packets));
                o.insert("wire_retries".into(), num(m.wire_retries));
                o.insert(
                    "tenant".into(),
                    m.tenant.as_deref().map(Json::from).unwrap_or(Json::Null),
                );
                o.insert("quantum_latency_us".into(), opt_num64(m.quantum_latency_us));
            }
        }
        Json::Obj(o)
    }
}

fn num(n: u64) -> Json {
    Json::Num(n as f64)
}

fn opt_num(n: Option<u32>) -> Json {
    n.map(Json::from).unwrap_or(Json::Null)
}

fn opt_num64(n: Option<u64>) -> Json {
    n.map(num).unwrap_or(Json::Null)
}

/// A bus consumer. `accept` returns `true` when the event was taken;
/// `false` means "busy — try me again later": the hub keeps the event
/// (and everything after it) in the sink's bounded buffer, in order.
pub trait Sink {
    fn accept(&mut self, seq: u64, event: &RunEvent) -> bool;
}

/// Handle for detaching a sink and reading its drop counter.
pub type SinkId = u64;

struct SinkSlot {
    id: SinkId,
    sink: Box<dyn Sink>,
    /// Undelivered backlog, oldest first, capped at `capacity`.
    buffer: VecDeque<(u64, RunEvent)>,
    capacity: usize,
    /// Events dropped because the buffer was full (slow sink).
    dropped: u64,
    /// Events handed to the sink so far.
    delivered: u64,
    /// Bus sequence number at attach time (a mid-run subscriber's
    /// cursor starts here, not at zero).
    attached_at: u64,
}

impl SinkSlot {
    /// Hand buffered events to the sink, oldest first, until it
    /// refuses one. Order is the arrival order; nothing is skipped.
    fn drain(&mut self) {
        while let Some((seq, ev)) = self.buffer.front() {
            if !self.sink.accept(*seq, ev) {
                break;
            }
            self.delivered += 1;
            self.buffer.pop_front();
        }
    }
}

#[derive(Default)]
struct Hub {
    /// Monotonic event counter; the per-sink cursor currency.
    seq: u64,
    slots: Vec<SinkSlot>,
    next_id: SinkId,
    /// FNV hashes of anomaly texts already mirrored ([`EventBus::emit_anomaly`]
    /// is called from the idempotent provenance path, so it dedupes).
    seen_anomalies: BTreeSet<u64>,
    /// True while a delivery pass has the slots checked out (sinks run
    /// with the hub unborrowed, so they may call back into the bus).
    delivering: bool,
    /// Events emitted re-entrantly from inside a sink, flushed by the
    /// outer delivery pass after its own event.
    pending: VecDeque<(u64, RunEvent)>,
    /// Detaches requested from inside a sink while the slots were
    /// checked out; applied when the delivery pass returns them.
    pending_detach: BTreeSet<SinkId>,
}

/// The per-run event hub: a cheaply clonable handle (the front end is
/// single-threaded, so sharing is `Rc<RefCell<..>>`, the same idiom as
/// the service's shared checkpointer). A default bus has no sinks and
/// makes [`EventBus::emit`] a counter bump — runs that nobody watches
/// pay nothing.
#[derive(Clone, Default)]
pub struct EventBus {
    hub: Rc<RefCell<Hub>>,
}

impl std::fmt::Debug for EventBus {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let hub = self.hub.borrow();
        f.debug_struct("EventBus")
            .field("seq", &hub.seq)
            .field("sinks", &hub.slots.len())
            .finish()
    }
}

impl EventBus {
    pub fn new() -> Self {
        Self::default()
    }

    /// Subscribe a sink (works mid-run) with the default buffer depth.
    pub fn attach(&self, sink: Box<dyn Sink>) -> SinkId {
        self.attach_buffered(sink, DEFAULT_SINK_CAPACITY)
    }

    /// Subscribe a sink with an explicit bounded buffer. `capacity` is
    /// the most undelivered events the hub will hold for it; beyond
    /// that, new events are counted in [`EventBus::dropped`] and lost
    /// to this sink (never to the others).
    pub fn attach_buffered(&self, sink: Box<dyn Sink>, capacity: usize) -> SinkId {
        let mut hub = self.hub.borrow_mut();
        let id = hub.next_id;
        hub.next_id += 1;
        let attached_at = hub.seq;
        hub.slots.push(SinkSlot {
            id,
            sink,
            buffer: VecDeque::new(),
            capacity: capacity.max(1),
            dropped: 0,
            delivered: 0,
            attached_at,
        });
        id
    }

    /// Unsubscribe; undelivered backlog is discarded. Safe to call from
    /// inside a [`Sink`]: mid-delivery the removal is deferred until the
    /// current pass hands the slots back.
    pub fn detach(&self, id: SinkId) {
        let mut hub = self.hub.borrow_mut();
        hub.slots.retain(|s| s.id != id);
        if hub.delivering {
            hub.pending_detach.insert(id);
        }
    }

    /// Whether anyone is listening — emission sites use this to skip
    /// building events (and sampling router stats) on unwatched runs.
    pub fn has_sinks(&self) -> bool {
        let hub = self.hub.borrow();
        // `delivering` implies at least one slot is checked out of the
        // hub for the duration of a delivery pass.
        !hub.slots.is_empty() || hub.delivering
    }

    /// Events published so far (the next event gets `seq() + 1`).
    pub fn seq(&self) -> u64 {
        self.hub.borrow().seq
    }

    /// Events a slow sink lost to its full buffer (`None`: unknown id).
    pub fn dropped(&self, id: SinkId) -> Option<u64> {
        self.hub.borrow().slots.iter().find(|s| s.id == id).map(|s| s.dropped)
    }

    /// Events actually handed to a sink so far (`None`: unknown id).
    pub fn delivered(&self, id: SinkId) -> Option<u64> {
        self.hub
            .borrow()
            .slots
            .iter()
            .find(|s| s.id == id)
            .map(|s| s.delivered)
    }

    /// The bus sequence number a sink subscribed at (`None`: unknown id).
    pub fn attached_at(&self, id: SinkId) -> Option<u64> {
        self.hub
            .borrow()
            .slots
            .iter()
            .find(|s| s.id == id)
            .map(|s| s.attached_at)
    }

    /// Publish one event to every sink. Never blocks: a sink that
    /// refuses delivery accumulates backlog in its bounded buffer, and
    /// a full buffer drops (and counts) the new event for that sink.
    ///
    /// Sinks run with the hub unborrowed, so a sink may re-enter the
    /// bus (emit, attach, detach, counters): re-entrant emissions are
    /// queued and flushed by the outer call, in order. The one caveat:
    /// per-sink counters ([`EventBus::dropped`] and friends) queried
    /// from *inside* a sink return `None` while the slots are checked
    /// out for delivery.
    pub fn emit(&self, event: RunEvent) {
        {
            let mut hub = self.hub.borrow_mut();
            hub.seq += 1;
            let seq = hub.seq;
            if hub.delivering {
                // Emitted from inside a sink: the outer delivery pass
                // flushes this after the event it is handing out now.
                hub.pending.push_back((seq, event));
                return;
            }
            if hub.slots.is_empty() {
                return;
            }
            hub.pending.push_back((seq, event));
            hub.delivering = true;
        }
        self.flush_pending();
    }

    /// Deliver queued events until none remain, checking the slots out
    /// of the hub for each pass so sinks run without the `RefCell`
    /// borrowed (re-entrant bus calls from a sink must not panic).
    fn flush_pending(&self) {
        loop {
            let ((seq, event), mut slots) = {
                let mut hub = self.hub.borrow_mut();
                match hub.pending.pop_front() {
                    Some(item) => (item, std::mem::take(&mut hub.slots)),
                    None => {
                        hub.delivering = false;
                        return;
                    }
                }
            };
            for slot in slots.iter_mut() {
                // A sink attached (re-entrantly) after this event was
                // sequenced never sees it — no replay of history.
                if seq <= slot.attached_at {
                    continue;
                }
                // Drain *first*: a sink that has become ready again
                // takes its backlog now, which may free the room this
                // event needs — dropping before draining would lose
                // the event that arrives at recovery time.
                slot.drain();
                if slot.buffer.len() >= slot.capacity {
                    // Dropping the *new* event (not the oldest) keeps
                    // what the sink eventually sees a strict
                    // prefix-in-order of the stream — late data beats
                    // reordered data.
                    slot.dropped += 1;
                } else {
                    slot.buffer.push_back((seq, event.clone()));
                    slot.drain();
                }
            }
            let mut hub = self.hub.borrow_mut();
            // Merge back, honouring anything a sink did re-entrantly:
            // detaches recorded while the slots were out, and sinks
            // attached mid-delivery (sitting in `hub.slots` now).
            let attached_during = std::mem::take(&mut hub.slots);
            if !hub.pending_detach.is_empty() {
                let gone = std::mem::take(&mut hub.pending_detach);
                slots.retain(|s| !gone.contains(&s.id));
            }
            slots.extend(attached_during);
            hub.slots = slots;
        }
    }

    /// Mirror a provenance anomaly, once per distinct text (the
    /// provenance path re-collects, so the mirror must be idempotent).
    pub fn emit_anomaly(&self, text: &str) {
        let h = crate::util::fnv1a_64(text.as_bytes());
        if !self.hub.borrow_mut().seen_anomalies.insert(h) {
            return;
        }
        self.emit(RunEvent::Anomaly { text: text.to_string() });
    }
}

// -- built-in sinks ----------------------------------------------------------

/// In-memory ring: keeps the most recent `capacity` events. Clonable —
/// keep one handle, attach the other — so tests and dashboards can read
/// while the bus writes.
#[derive(Clone)]
pub struct RingSink {
    ring: Rc<RefCell<VecDeque<(u64, RunEvent)>>>,
    capacity: usize,
}

impl RingSink {
    pub fn new(capacity: usize) -> Self {
        Self {
            ring: Rc::new(RefCell::new(VecDeque::new())),
            capacity: capacity.max(1),
        }
    }

    /// Snapshot of the ring, oldest first, with sequence numbers.
    pub fn events(&self) -> Vec<(u64, RunEvent)> {
        self.ring.borrow().iter().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.ring.borrow().len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.borrow().is_empty()
    }
}

impl Sink for RingSink {
    fn accept(&mut self, seq: u64, event: &RunEvent) -> bool {
        let mut ring = self.ring.borrow_mut();
        if ring.len() == self.capacity {
            ring.pop_front();
        }
        ring.push_back((seq, event.clone()));
        true
    }
}

/// Calls a closure per event (live dashboards, test probes).
pub struct CallbackSink<F: FnMut(u64, &RunEvent)> {
    f: F,
}

impl<F: FnMut(u64, &RunEvent)> CallbackSink<F> {
    pub fn new(f: F) -> Self {
        Self { f }
    }
}

impl<F: FnMut(u64, &RunEvent)> Sink for CallbackSink<F> {
    fn accept(&mut self, seq: u64, event: &RunEvent) -> bool {
        (self.f)(seq, event);
        true
    }
}

/// Appends one compact JSON object per event to a file — the durable
/// tail a dashboard (or `tail -f`) follows.
pub struct JsonlSink {
    out: std::io::BufWriter<std::fs::File>,
}

impl JsonlSink {
    pub fn create(path: impl AsRef<std::path::Path>) -> anyhow::Result<Self> {
        let file = std::fs::File::create(path.as_ref())?;
        Ok(Self { out: std::io::BufWriter::new(file) })
    }
}

impl Sink for JsonlSink {
    fn accept(&mut self, seq: u64, event: &RunEvent) -> bool {
        let mut obj = match event.to_json() {
            Json::Obj(o) => o,
            other => BTreeMap::from([("event".to_string(), other)]),
        };
        obj.insert("seq".into(), num(seq));
        // A write error must not take the run down: the bus is
        // observation-only, so the sink just stops consuming.
        writeln!(self.out, "{}", Json::Obj(obj).to_string_compact()).is_ok()
    }
}

impl Drop for JsonlSink {
    fn drop(&mut self) {
        let _ = self.out.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(n: u64) -> RunEvent {
        RunEvent::CheckpointCaptured { tick: n }
    }

    #[test]
    fn fan_out_delivers_to_every_sink_in_order() {
        let bus = EventBus::new();
        let a = RingSink::new(64);
        let b = RingSink::new(64);
        bus.attach(Box::new(a.clone()));
        bus.attach(Box::new(b.clone()));
        for n in 0..5 {
            bus.emit(ev(n));
        }
        assert_eq!(a.events().len(), 5);
        assert_eq!(a.events(), b.events());
        let seqs: Vec<u64> = a.events().iter().map(|(s, _)| *s).collect();
        assert_eq!(seqs, vec![1, 2, 3, 4, 5], "sequence numbers are monotonic from 1");
    }

    #[test]
    fn mid_run_subscriber_starts_at_current_cursor() {
        let bus = EventBus::new();
        for n in 0..3 {
            bus.emit(ev(n));
        }
        let late = RingSink::new(64);
        let id = bus.attach(Box::new(late.clone()));
        assert_eq!(bus.attached_at(id), Some(3));
        bus.emit(ev(99));
        let got = late.events();
        assert_eq!(got.len(), 1, "no replay of history");
        assert_eq!(got[0].0, 4);
    }

    #[test]
    fn slow_sink_drops_new_events_counted_never_reordered() {
        let bus = EventBus::new();
        // Refuses everything until opened, then takes the backlog.
        let open = Rc::new(RefCell::new(false));
        let seen: Rc<RefCell<Vec<u64>>> = Rc::default();
        let (o2, s2) = (open.clone(), seen.clone());
        let id = bus.attach_buffered(
            Box::new(CallbackGate { open: o2, seen: s2 }),
            3,
        );
        let healthy = RingSink::new(64);
        bus.attach(Box::new(healthy.clone()));
        for n in 0..8 {
            bus.emit(ev(n));
        }
        // Buffer held 3, the other 5 dropped; the healthy sink saw all 8.
        assert_eq!(bus.dropped(id), Some(5));
        assert_eq!(healthy.len(), 8);
        assert!(seen.borrow().is_empty());
        *open.borrow_mut() = true;
        bus.emit(ev(100));
        // Backlog (1,2,3) then the fresh event (9) — strictly in order,
        // the overflow gap is a gap, never a reorder.
        assert_eq!(*seen.borrow(), vec![1, 2, 3, 9]);
        assert_eq!(bus.delivered(id), Some(4));
    }

    struct CallbackGate {
        open: Rc<RefCell<bool>>,
        seen: Rc<RefCell<Vec<u64>>>,
    }

    impl Sink for CallbackGate {
        fn accept(&mut self, seq: u64, _event: &RunEvent) -> bool {
            if !*self.open.borrow() {
                return false;
            }
            self.seen.borrow_mut().push(seq);
            true
        }
    }

    #[test]
    fn reentrant_emit_from_sink_queues_after_current_event() {
        let bus = EventBus::new();
        let ring = RingSink::new(8);
        bus.attach(Box::new(ring.clone()));
        let b2 = bus.clone();
        bus.attach(Box::new(CallbackSink::new(move |_, event| {
            if matches!(event, RunEvent::CheckpointCaptured { tick: 1 }) {
                b2.emit(ev(2));
            }
        })));
        bus.emit(ev(1));
        let ticks: Vec<u64> = ring
            .events()
            .iter()
            .map(|(_, e)| match e {
                RunEvent::CheckpointCaptured { tick } => *tick,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(ticks, vec![1, 2], "re-entrant event lands after the one in flight");
        assert_eq!(bus.seq(), 2);
    }

    #[test]
    fn sink_may_detach_itself_mid_delivery() {
        let bus = EventBus::new();
        let seen: Rc<RefCell<Vec<u64>>> = Rc::default();
        let id_cell: Rc<RefCell<Option<SinkId>>> = Rc::default();
        let (b2, s2, c2) = (bus.clone(), seen.clone(), id_cell.clone());
        let id = bus.attach(Box::new(CallbackSink::new(move |seq, _| {
            s2.borrow_mut().push(seq);
            if let Some(id) = *c2.borrow() {
                b2.detach(id);
            }
        })));
        *id_cell.borrow_mut() = Some(id);
        bus.emit(ev(1));
        bus.emit(ev(2));
        assert_eq!(*seen.borrow(), vec![1], "gone after detaching during seq 1");
        assert!(!bus.has_sinks());
    }

    #[test]
    fn sink_attached_mid_delivery_misses_current_event() {
        let bus = EventBus::new();
        let late = RingSink::new(8);
        let attached = Rc::new(RefCell::new(false));
        let (b2, l2, a2) = (bus.clone(), late.clone(), attached.clone());
        bus.attach(Box::new(CallbackSink::new(move |_, _| {
            if !*a2.borrow() {
                *a2.borrow_mut() = true;
                b2.attach(Box::new(l2.clone()));
            }
        })));
        bus.emit(ev(1));
        assert!(late.is_empty(), "no replay of the event being delivered");
        bus.emit(ev(2));
        let seqs: Vec<u64> = late.events().iter().map(|(s, _)| *s).collect();
        assert_eq!(seqs, vec![2]);
    }

    #[test]
    fn detach_stops_delivery() {
        let bus = EventBus::new();
        let a = RingSink::new(8);
        let id = bus.attach(Box::new(a.clone()));
        bus.emit(ev(1));
        bus.detach(id);
        bus.emit(ev(2));
        assert_eq!(a.len(), 1);
        assert!(!bus.has_sinks());
    }

    #[test]
    fn anomaly_mirror_dedupes_by_text() {
        let bus = EventBus::new();
        let a = RingSink::new(8);
        bus.attach(Box::new(a.clone()));
        bus.emit_anomaly("router (0, 0): 3 dropped packets");
        bus.emit_anomaly("router (0, 0): 3 dropped packets");
        bus.emit_anomaly("core 0,0,4 hit a runtime error");
        assert_eq!(a.len(), 2);
    }

    #[test]
    fn ring_keeps_most_recent() {
        let bus = EventBus::new();
        let a = RingSink::new(2);
        bus.attach(Box::new(a.clone()));
        for n in 0..5 {
            bus.emit(ev(n));
        }
        let ticks: Vec<u64> = a
            .events()
            .iter()
            .map(|(_, e)| match e {
                RunEvent::CheckpointCaptured { tick } => *tick,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(ticks, vec![3, 4]);
    }

    #[test]
    fn events_serialize_to_single_json_lines() {
        let m = RunEvent::Metrics(Metrics {
            tick: 100,
            sim_ns: 100_000_000,
            ticks_per_sec: 123.5,
            packets_per_sec: 4.0,
            packets: 4,
            wire_retries: 0,
            tenant: Some("a".into()),
            quantum_latency_us: Some(250),
        });
        let line = m.to_json().to_string_compact();
        assert!(!line.contains('\n'));
        let back = Json::parse(&line).unwrap();
        assert_eq!(back.get("type").unwrap().as_str(), Some("metrics"));
        assert_eq!(back.get("tick").unwrap().as_usize(), Some(100));
        assert_eq!(back.get("tenant").unwrap().as_str(), Some("a"));
    }
}
