//! The SpiNNaker Datagram Protocol (SDP) and the SCP command layer on
//! top of it (§3; Furber et al. 2014).
//!
//! An SDP message carries up to 256 bytes of SCP/user data plus an
//! 8-byte header routed by chip coordinates and a 5-bit cpu + 3-bit
//! port. Messages to/from the outside world are encapsulated in UDP by
//! the Ethernet-chip monitor using the IP tag table.

use crate::machine::CoreLocation;
use crate::util::bytes::{ByteReader, ByteWriter};

/// SDP port of the SCAMP monitor process.
pub const SDP_PORT_MONITOR: u8 = 0;

/// Maximum SDP payload (§6.8: "each SDP message can request the reading
/// of up to 256 bytes").
pub const SDP_MAX_DATA: usize = 256 + 16; // 256 user bytes + SCP header

/// The 8-byte SDP header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SdpHeader {
    /// 0x87 = reply expected, 0x07 = no reply.
    pub flags: u8,
    /// IP tag for host-bound traffic (0xff = none).
    pub tag: u8,
    pub dest_port: u8,
    pub dest_cpu: u8,
    pub dest_x: u8,
    pub dest_y: u8,
    pub src_port: u8,
    pub src_cpu: u8,
    pub src_x: u8,
    pub src_y: u8,
}

impl SdpHeader {
    pub fn to_core(dest: CoreLocation, port: u8) -> Self {
        Self {
            flags: 0x07,
            tag: 0xff,
            dest_port: port,
            dest_cpu: dest.p,
            dest_x: dest.x as u8,
            dest_y: dest.y as u8,
            src_port: 7,
            src_cpu: 31,
            src_x: 0,
            src_y: 0,
        }
    }

    pub fn dest(&self) -> CoreLocation {
        CoreLocation::new(self.dest_x as u32, self.dest_y as u32, self.dest_cpu)
    }

    pub fn reply_expected(&self) -> bool {
        self.flags & 0x80 != 0
    }
}

/// An SDP message: header + data.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SdpMessage {
    pub header: SdpHeader,
    pub data: Vec<u8>,
}

impl SdpMessage {
    pub fn new(header: SdpHeader, data: Vec<u8>) -> Self {
        debug_assert!(data.len() <= SDP_MAX_DATA, "SDP payload too large");
        Self { header, data }
    }

    /// Wire encoding (as carried inside a UDP frame).
    pub fn encode(&self) -> Vec<u8> {
        let h = &self.header;
        let mut w = ByteWriter::new();
        // 2 bytes padding as in the real UDP encapsulation.
        w.u16(0);
        w.u8(h.flags).u8(h.tag);
        // dest/src port+cpu packed: port in top 3 bits, cpu in low 5.
        w.u8((h.dest_port << 5) | (h.dest_cpu & 0x1f));
        w.u8((h.src_port << 5) | (h.src_cpu & 0x1f));
        w.u8(h.dest_y).u8(h.dest_x);
        w.u8(h.src_y).u8(h.src_x);
        w.bytes(&self.data);
        w.finish()
    }

    pub fn decode(buf: &[u8]) -> anyhow::Result<Self> {
        let mut r = ByteReader::new(buf);
        let _pad = r.u16()?;
        let flags = r.u8()?;
        let tag = r.u8()?;
        let dp = r.u8()?;
        let sp = r.u8()?;
        let dest_y = r.u8()?;
        let dest_x = r.u8()?;
        let src_y = r.u8()?;
        let src_x = r.u8()?;
        let data = r.rest().to_vec();
        Ok(Self {
            header: SdpHeader {
                flags,
                tag,
                dest_port: dp >> 5,
                dest_cpu: dp & 0x1f,
                dest_x,
                dest_y,
                src_port: sp >> 5,
                src_cpu: sp & 0x1f,
                src_x,
                src_y,
            },
            data,
        })
    }
}

/// SCP commands used by the tools (subset of the SCAMP command set).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u16)]
pub enum ScpCommand {
    Version = 0,
    Read = 2,
    Write = 3,
    /// Load an application onto cores (stand-in for APLX flood fill).
    AppLoad = 4,
    /// Load routing-table entries.
    RouterInit = 5,
    IpTagSet = 26,
    /// Signal cores (start / sync / pause / stop).
    Signal = 22,
    /// Read a core's run state.
    CoreState = 23,
}

impl ScpCommand {
    pub fn from_u16(v: u16) -> Option<Self> {
        Some(match v {
            0 => Self::Version,
            2 => Self::Read,
            3 => Self::Write,
            4 => Self::AppLoad,
            5 => Self::RouterInit,
            26 => Self::IpTagSet,
            22 => Self::Signal,
            23 => Self::CoreState,
            _ => return None,
        })
    }
}

/// An SCP request (rides in SDP data).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScpRequest {
    pub cmd: ScpCommand,
    pub seq: u16,
    pub arg1: u32,
    pub arg2: u32,
    pub arg3: u32,
    pub data: Vec<u8>,
}

impl ScpRequest {
    pub fn new(cmd: ScpCommand, arg1: u32, arg2: u32, arg3: u32) -> Self {
        Self { cmd, seq: 0, arg1, arg2, arg3, data: Vec::new() }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.u16(self.cmd as u16).u16(self.seq);
        w.u32(self.arg1).u32(self.arg2).u32(self.arg3);
        w.bytes(&self.data);
        w.finish()
    }

    pub fn decode(buf: &[u8]) -> anyhow::Result<Self> {
        let mut r = ByteReader::new(buf);
        let cmd_raw = r.u16()?;
        let cmd = ScpCommand::from_u16(cmd_raw)
            .ok_or_else(|| anyhow::anyhow!("unknown SCP command {cmd_raw}"))?;
        let seq = r.u16()?;
        let arg1 = r.u32()?;
        let arg2 = r.u32()?;
        let arg3 = r.u32()?;
        let data = r.rest().to_vec();
        Ok(Self { cmd, seq, arg1, arg2, arg3, data })
    }
}

/// An SCP response: result code + payload.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScpResponse {
    pub result: u16, // 0x80 = OK
    pub seq: u16,
    pub data: Vec<u8>,
}

pub const SCP_OK: u16 = 0x80;

impl ScpResponse {
    pub fn ok(seq: u16, data: Vec<u8>) -> Self {
        Self { result: SCP_OK, seq, data }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.u16(self.result).u16(self.seq).bytes(&self.data);
        w.finish()
    }

    pub fn decode(buf: &[u8]) -> anyhow::Result<Self> {
        let mut r = ByteReader::new(buf);
        let result = r.u16()?;
        let seq = r.u16()?;
        let data = r.rest().to_vec();
        Ok(Self { result, seq, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sdp_round_trip() {
        let msg = SdpMessage::new(
            SdpHeader::to_core(CoreLocation::new(3, 4, 7), 1),
            vec![1, 2, 3, 4, 5],
        );
        let decoded = SdpMessage::decode(&msg.encode()).unwrap();
        assert_eq!(decoded, msg);
        assert_eq!(decoded.header.dest(), CoreLocation::new(3, 4, 7));
    }

    #[test]
    fn port_cpu_packing() {
        let mut h = SdpHeader::to_core(CoreLocation::new(0, 0, 17), 5);
        h.src_port = 2;
        h.src_cpu = 9;
        let msg = SdpMessage::new(h, vec![]);
        let d = SdpMessage::decode(&msg.encode()).unwrap();
        assert_eq!(d.header.dest_port, 5);
        assert_eq!(d.header.dest_cpu, 17);
        assert_eq!(d.header.src_port, 2);
        assert_eq!(d.header.src_cpu, 9);
    }

    #[test]
    fn scp_round_trip() {
        let mut req = ScpRequest::new(ScpCommand::Read, 0x6000_0000, 256, 0);
        req.seq = 42;
        req.data = vec![9, 9];
        let d = ScpRequest::decode(&req.encode()).unwrap();
        assert_eq!(d, req);
    }

    #[test]
    fn scp_response_round_trip() {
        let resp = ScpResponse::ok(7, vec![1, 2, 3]);
        let d = ScpResponse::decode(&resp.encode()).unwrap();
        assert_eq!(d, resp);
        assert_eq!(d.result, SCP_OK);
    }

    #[test]
    fn unknown_command_rejected() {
        let mut bad = ScpRequest::new(ScpCommand::Version, 0, 0, 0).encode();
        bad[0] = 0xee;
        bad[1] = 0xee;
        assert!(ScpRequest::decode(&bad).is_err());
    }

    #[test]
    fn reply_flag() {
        let mut h = SdpHeader::to_core(CoreLocation::new(0, 0, 1), 0);
        assert!(!h.reply_expected());
        h.flags = 0x87;
        assert!(h.reply_expected());
    }
}
