//! On-wire protocol codecs: SDP (§3), SCP command framing, the EIEIO
//! live-event protocol (§6.9; Rast et al. 2015), and the bulk
//! data-plane framing of §6.8 ([`bulk`]).
//!
//! These are real byte-level encoders/decoders — the simulated machine
//! and the host-side tools exchange exactly these frames, so the codec
//! layer is exercised the way a physical deployment would exercise it.

pub mod bulk;
mod eieio;
mod sdp;

pub use eieio::{EieioHeader, EieioMessage, EieioType};
pub use sdp::{ScpCommand, ScpRequest, ScpResponse, SdpHeader, SdpMessage, SDP_PORT_MONITOR};
