//! The EIEIO event protocol (§6.9; Rast et al. 2015): the wire format
//! the Live Packet Gatherer emits and the Reverse IP Tag Multicast
//! Source consumes, carrying batched multicast events to/from external
//! applications.

use crate::util::bytes::{ByteReader, ByteWriter};

/// Event encodings (the subset the tools use).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EieioType {
    /// 32-bit keys, no payload.
    Key32,
    /// 32-bit keys each followed by a 32-bit payload.
    Key32Payload,
}

impl EieioType {
    fn code(self) -> u8 {
        match self {
            EieioType::Key32 => 2,
            EieioType::Key32Payload => 3,
        }
    }

    fn from_code(c: u8) -> anyhow::Result<Self> {
        Ok(match c {
            2 => EieioType::Key32,
            3 => EieioType::Key32Payload,
            other => anyhow::bail!("unsupported EIEIO type {other}"),
        })
    }
}

/// EIEIO data header: count + type (+ optional timestamp tag, unused).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EieioHeader {
    pub ty: EieioType,
    pub count: u8,
}

/// A batch of events.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EieioMessage {
    pub ty: EieioType,
    /// (key, payload) pairs; payload is None for Key32.
    pub events: Vec<(u32, Option<u32>)>,
}

impl EieioMessage {
    pub fn keys(keys: &[u32]) -> Self {
        Self {
            ty: EieioType::Key32,
            events: keys.iter().map(|k| (*k, None)).collect(),
        }
    }

    pub fn with_payloads(pairs: &[(u32, u32)]) -> Self {
        Self {
            ty: EieioType::Key32Payload,
            events: pairs.iter().map(|(k, p)| (*k, Some(*p))).collect(),
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        debug_assert!(self.events.len() <= 255);
        let mut w = ByteWriter::new();
        w.u8(self.events.len() as u8);
        w.u8(self.ty.code() << 4); // type in the high nibble, flags clear
        for (key, payload) in &self.events {
            w.u32(*key);
            if self.ty == EieioType::Key32Payload {
                w.u32(payload.unwrap_or(0));
            }
        }
        w.finish()
    }

    pub fn decode(buf: &[u8]) -> anyhow::Result<Self> {
        let mut r = ByteReader::new(buf);
        let count = r.u8()?;
        let ty = EieioType::from_code(r.u8()? >> 4)?;
        let mut events = Vec::with_capacity(count as usize);
        for _ in 0..count {
            let key = r.u32()?;
            let payload = if ty == EieioType::Key32Payload {
                Some(r.u32()?)
            } else {
                None
            };
            events.push((key, payload));
        }
        anyhow::ensure!(r.remaining() == 0, "trailing EIEIO bytes");
        Ok(Self { ty, events })
    }

    /// Split a long event list into <=255-event messages.
    pub fn batched(ty: EieioType, events: &[(u32, Option<u32>)]) -> Vec<EieioMessage> {
        events
            .chunks(255)
            .map(|chunk| EieioMessage { ty, events: chunk.to_vec() })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn key32_round_trip() {
        let m = EieioMessage::keys(&[1, 2, 0xdead_beef]);
        let d = EieioMessage::decode(&m.encode()).unwrap();
        assert_eq!(d, m);
    }

    #[test]
    fn key32_payload_round_trip() {
        let m = EieioMessage::with_payloads(&[(1, 100), (2, 200)]);
        let d = EieioMessage::decode(&m.encode()).unwrap();
        assert_eq!(d, m);
    }

    #[test]
    fn empty_message() {
        let m = EieioMessage::keys(&[]);
        let d = EieioMessage::decode(&m.encode()).unwrap();
        assert_eq!(d.events.len(), 0);
    }

    #[test]
    fn batching_splits_at_255() {
        let events: Vec<(u32, Option<u32>)> = (0..600).map(|k| (k, None)).collect();
        let batches = EieioMessage::batched(EieioType::Key32, &events);
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].events.len(), 255);
        assert_eq!(batches[2].events.len(), 90);
        let total: usize = batches.iter().map(|b| b.events.len()).sum();
        assert_eq!(total, 600);
    }

    #[test]
    fn truncated_rejected() {
        let m = EieioMessage::keys(&[1, 2, 3]).encode();
        assert!(EieioMessage::decode(&m[..m.len() - 2]).is_err());
    }

    #[test]
    fn unknown_type_rejected() {
        let mut m = EieioMessage::keys(&[1]).encode();
        m[1] = 0xf0;
        assert!(EieioMessage::decode(&m).is_err());
    }
}
