//! Wire framing of the bulk data plane (§6.8 and its data-in mirror).
//!
//! The fast data paths move SDRAM contents between the host and
//! arbitrary chips in 256-byte *frames* — 64 little-endian words, the
//! largest unit one SDP message can carry. Frames are sequence-numbered
//! from 0 within one transfer, so either end can name exactly which
//! frames it is missing and have only those re-sent.
//!
//! Three codecs live here:
//!
//! - **data-in frames** (host → board fan-out core): one UDP frame per
//!   256-byte chunk, carrying the target stream key, the sequence
//!   number and the payload words;
//! - **write-session commands** (host → per-chip writer core over SDP):
//!   open a write session at an SDRAM address, or ask for the missing
//!   sequence numbers of the current session;
//! - **missing-sequence reports** (writer core → host over a tagged SDP
//!   message): the re-request vocabulary of the data-in direction.
//!
//! The extraction direction's equivalents (read command, re-request,
//! host-side reassembly) predate this module and live with the reader /
//! gatherer cores in [`crate::apps::speedup`]; both directions share
//! the frame geometry defined here.

use crate::util::bytes::{ByteReader, ByteWriter};

/// Words in one frame (64 × 4 B = 256 B, the SDP data limit of §6.8).
pub const WORDS_PER_FRAME: usize = 64;

/// Bytes of payload in one full frame.
pub const BYTES_PER_FRAME: usize = WORDS_PER_FRAME * 4;

/// Magic of a data-in frame (host → fan-out core).
pub const DATA_FRAME_MAGIC: u32 = 0xDA7A_0013;

/// Magic of a write-session open command (host → writer core).
pub const WRITE_CMD_MAGIC: u32 = 0xDA7A_0010;

/// Magic of a missing-sequence query (host → writer core).
pub const CHECK_CMD_MAGIC: u32 = 0xDA7A_0011;

/// Magic of a missing-sequence report (writer core → host).
pub const MISSING_REPORT_MAGIC: u32 = 0xDA7A_0012;

/// Sequence numbers per missing-report SDP message (fits the 256-byte
/// SDP payload next to the three header words).
pub const SEQS_PER_REPORT: usize = 60;

/// Number of frames a transfer of `len` bytes needs.
pub fn frames_of(len: usize) -> usize {
    len.div_ceil(BYTES_PER_FRAME)
}

/// The byte range of frame `seq` within a transfer of `len` bytes.
pub fn frame_range(seq: u32, len: usize) -> std::ops::Range<usize> {
    let lo = seq as usize * BYTES_PER_FRAME;
    lo..len.min(lo + BYTES_PER_FRAME)
}

/// Encode one data-in frame: `[magic, key, seq, words…]`, the tail word
/// zero-padded exactly as the SDRAM allocator pads segments.
pub fn encode_data_frame(key: u32, seq: u32, data: &[u8]) -> Vec<u8> {
    debug_assert!(data.len() <= BYTES_PER_FRAME, "frame payload too large");
    let mut w = ByteWriter::new();
    w.u32(DATA_FRAME_MAGIC);
    w.u32(key);
    w.u32(seq);
    w.bytes(data);
    // Pad the tail to a whole word so the fan-out core only ever
    // handles full 32-bit packet payloads.
    for _ in 0..data.len().div_ceil(4) * 4 - data.len() {
        w.u8(0);
    }
    w.finish()
}

/// Decoded form of [`encode_data_frame`].
pub struct DataInFrame {
    /// Stream key of the target chip's writer core.
    pub key: u32,
    /// Frame sequence number within the transfer.
    pub seq: u32,
    /// The frame's payload words.
    pub words: Vec<u32>,
}

/// Decode a data-in frame.
pub fn decode_data_frame(buf: &[u8]) -> anyhow::Result<DataInFrame> {
    let mut r = ByteReader::new(buf);
    let magic = r.u32()?;
    anyhow::ensure!(magic == DATA_FRAME_MAGIC, "not a data-in frame ({magic:#x})");
    let key = r.u32()?;
    let seq = r.u32()?;
    anyhow::ensure!(r.remaining() % 4 == 0, "data-in frame tail not word-aligned");
    let words = r.u32s(r.remaining() / 4)?;
    anyhow::ensure!(
        (1..=WORDS_PER_FRAME).contains(&words.len()),
        "data-in frame with {} words",
        words.len()
    );
    Ok(DataInFrame { key, seq, words })
}

/// Encode the write-session open command: stream `len` bytes to `addr`.
pub fn encode_write_command(addr: u32, len: u32) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.u32(WRITE_CMD_MAGIC);
    w.u32(addr);
    w.u32(len);
    w.finish()
}

/// Encode the missing-sequence query for the current write session.
pub fn encode_check_command() -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.u32(CHECK_CMD_MAGIC);
    w.finish()
}

/// Encode the missing-sequence report messages for one query: each
/// message is `[magic, total_missing, n_here, seqs…]`, chunked to the
/// SDP payload limit. A session with nothing missing still produces one
/// (empty) report so the host can tell "complete" from "no answer".
pub fn encode_missing_reports(missing: &[u32]) -> Vec<Vec<u8>> {
    let mut out = Vec::new();
    let mut emit = |chunk: &[u32]| {
        let mut w = ByteWriter::new();
        w.u32(MISSING_REPORT_MAGIC);
        w.u32(missing.len() as u32);
        w.u32(chunk.len() as u32);
        w.u32s(chunk);
        out.push(w.finish());
    };
    if missing.is_empty() {
        emit(&[]);
    } else {
        for chunk in missing.chunks(SEQS_PER_REPORT) {
            emit(chunk);
        }
    }
    out
}

/// Decode one missing-sequence report message into `(total, seqs)`.
pub fn decode_missing_report(buf: &[u8]) -> anyhow::Result<(u32, Vec<u32>)> {
    let mut r = ByteReader::new(buf);
    let magic = r.u32()?;
    anyhow::ensure!(magic == MISSING_REPORT_MAGIC, "not a missing report ({magic:#x})");
    let total = r.u32()?;
    let n = r.u32()?;
    Ok((total, r.u32s(n as usize)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn data_frame_round_trip() {
        let data: Vec<u8> = (0..=255).collect();
        let f = decode_data_frame(&encode_data_frame(0xFF80_0004, 9, &data)).unwrap();
        assert_eq!(f.key, 0xFF80_0004);
        assert_eq!(f.seq, 9);
        assert_eq!(f.words.len(), WORDS_PER_FRAME);
        assert_eq!(f.words[0], u32::from_le_bytes([0, 1, 2, 3]));
    }

    #[test]
    fn tail_frame_pads_to_word() {
        let f = decode_data_frame(&encode_data_frame(2, 0, &[7, 8, 9])).unwrap();
        assert_eq!(f.words, vec![u32::from_le_bytes([7, 8, 9, 0])]);
    }

    #[test]
    fn frame_geometry() {
        assert_eq!(frames_of(0), 0);
        assert_eq!(frames_of(1), 1);
        assert_eq!(frames_of(256), 1);
        assert_eq!(frames_of(257), 2);
        assert_eq!(frame_range(1, 300), 256..300);
    }

    #[test]
    fn missing_reports_chunk_and_round_trip() {
        let missing: Vec<u32> = (0..150).collect();
        let msgs = encode_missing_reports(&missing);
        assert_eq!(msgs.len(), 3);
        let mut got = Vec::new();
        for m in &msgs {
            let (total, seqs) = decode_missing_report(m).unwrap();
            assert_eq!(total, 150);
            got.extend(seqs);
        }
        assert_eq!(got, missing);
    }

    #[test]
    fn empty_report_still_answers() {
        let msgs = encode_missing_reports(&[]);
        assert_eq!(msgs.len(), 1);
        let (total, seqs) = decode_missing_report(&msgs[0]).unwrap();
        assert_eq!(total, 0);
        assert!(seqs.is_empty());
    }
}
