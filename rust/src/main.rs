//! The `spinntools` CLI: run the paper's workloads and experiments from
//! the command line (hand-rolled argument parsing — the offline vendor
//! bundle has no clap).

use spinntools::apps::networks::{build_conway_grid, build_microcircuit, firing_rates};
use spinntools::front::{ExtractionMethod, MachineSpec, SpiNNTools, ToolsConfig};
use spinntools::machine::MachineBuilder;

const USAGE: &str = "\
spinntools — the SpiNNaker execution engine (simulated), Rowley et al. 2018

USAGE:
  spinntools info [boards]             describe a (virtual) machine
  spinntools conway [side] [steps]     run Conway's Game of Life (§7.1)
  spinntools snn [scale] [run_ms]      run the cortical microcircuit (§7.2)
  spinntools extract-bench             Figure-11 extraction throughputs (E1)
  spinntools help
";

fn arg<T: std::str::FromStr>(args: &[String], i: usize, default: T) -> T {
    args.get(i).and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("info") => info(arg(&args, 1, 1)),
        Some("conway") => conway(arg(&args, 1, 16), arg(&args, 2, 16)),
        Some("snn") => snn(arg(&args, 1, 0.02), arg(&args, 2, 200)),
        Some("extract-bench") => extract_bench(),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn info(boards: u32) -> anyhow::Result<()> {
    let machine = MachineBuilder::boards(boards).build();
    println!("machine: {} board(s)", boards);
    println!("  dimensions:        {} x {} (wrap: {})", machine.width, machine.height, machine.wrap);
    println!("  chips:             {}", machine.n_chips());
    println!("  application cores: {}", machine.n_application_cores());
    println!("  user SDRAM:        {} MiB", machine.total_user_sdram() / (1024 * 1024));
    println!("  ethernet chips:    {}", machine.ethernet_chips().count());
    Ok(())
}

fn conway(side: u32, steps: u64) -> anyhow::Result<()> {
    let spec = if side * side <= 51 { MachineSpec::Spinn3 } else { MachineSpec::Spinn5 };
    let mut tools = SpiNNTools::new(
        ToolsConfig::new(spec).with_extraction(ExtractionMethod::FastMulticast),
    )?;
    let live: Vec<(u32, u32)> = (0..side)
        .flat_map(|r| (0..side).map(move |c| (r, c)))
        .filter(|(r, c)| (r * 7 + c * 3) % 5 < 2)
        .collect();
    let ids = build_conway_grid(&mut tools, side, side, &live)?;
    tools.run_ticks(steps)?;
    for r in 0..side {
        let row: String = (0..side)
            .map(|c| {
                let rec = tools.recording(ids[(r * side + c) as usize]);
                if rec.last().copied().unwrap_or(0) == 1 { '#' } else { '.' }
            })
            .collect();
        println!("{row}");
    }
    let prov = tools.provenance();
    println!(
        "\n{side}x{side} board, {steps} steps: {} packets, {} dropped",
        tools.sim_mut().map(|s| s.stats.mc_sent).unwrap_or(0),
        prov.total_dropped()
    );
    tools.stop()
}

fn snn(scale: f64, run_ms: u64) -> anyhow::Result<()> {
    let spec = if scale > 0.05 { MachineSpec::Boards(3) } else { MachineSpec::Spinn5 };
    let mut tools = SpiNNTools::new(ToolsConfig::new(spec).with_artifacts())?;
    let circuit = build_microcircuit(&mut tools, scale, 20260710, true)?;
    let n: u32 = circuit.sizes.values().sum();
    println!("running {n} neurons for {run_ms} ms...");
    tools.run_ms(run_ms)?;
    for (name, rate) in firing_rates(&tools, &circuit, run_ms as f64) {
        println!("  {name:>6}: {rate:6.2} Hz");
    }
    tools.stop()
}

fn extract_bench() -> anyhow::Result<()> {
    use spinntools::front::{DataPlaneOptions, FastPath};
    use spinntools::simulator::{scamp, SimConfig, SimMachine};
    let machine = MachineBuilder::spinn5().build();
    let mut sim = SimMachine::boot(machine, SimConfig::default());
    let len = 1024 * 1024;
    let mut next = std::collections::BTreeMap::new();
    let fp = FastPath::install(
        &mut sim,
        &[(0, 0), (7, 7)],
        move |chip| {
            let n = next.entry(chip).or_insert(17u8);
            let c = *n;
            *n -= 1;
            Some(c)
        },
        &DataPlaneOptions::default(),
    )?;
    scamp::signal_start(&mut sim)?;
    let mbps = |bytes: usize, ns: u64| bytes as f64 * 8.0 / (ns as f64 / 1e9) / 1e6;
    for chip in [(0u32, 0u32), (7, 7)] {
        let addr = scamp::alloc_sdram(&mut sim, chip, len as u32)?;
        let t0 = sim.now_ns();
        scamp::read_sdram(&mut sim, chip, addr, len)?;
        let t_scamp = sim.now_ns() - t0;
        let t1 = sim.now_ns();
        fp.read(&mut sim, chip, addr, len)?;
        let t_fast = sim.now_ns() - t1;
        println!(
            "chip {chip:?}: scamp {:.2} Mb/s, stream {:.2} Mb/s",
            mbps(len, t_scamp),
            mbps(len, t_fast)
        );
    }
    Ok(())
}
