//! E13 — incremental re-mapping equivalence (DESIGN.md §7).
//!
//! The contract of the §6.5 "graph changed" branch: `mutate → run`
//! through the incremental reconcile path must produce recordings
//! **byte-identical** to a fresh `SpiNNTools` built directly from the
//! final graph and run for the same duration — across add-vertex,
//! add-edge and remove-vertex deltas, at mapping-pool widths 1/2/8 —
//! while re-running strictly fewer pipeline stages than the stage
//! count.
//!
//! Cells are identified by grid position, not `VertexId`: the two tools
//! instances number vertices differently (the incremental one carries
//! tombstones), and key values / placements legitimately differ — only
//! the *recorded behaviour* must match.

use std::collections::{BTreeMap, BTreeSet};

use spinntools::apps::conway::{ConwayCellVertex, STATE_PARTITION};
use spinntools::front::{MachineSpec, SpiNNTools, ToolsConfig};
use spinntools::graph::VertexId;
use spinntools::util::{prop, SplitMix64};

type Pos = (u32, u32);

/// A replayable workload description.
#[derive(Clone)]
struct Model {
    cells: BTreeMap<Pos, bool>,
    /// Directed edges, all in [`STATE_PARTITION`].
    edges: BTreeSet<(Pos, Pos)>,
}

impl Model {
    /// A `rows x cols` Conway grid with 8-neighbour links and a seeded
    /// alive pattern.
    fn grid(rows: u32, cols: u32, rng: &mut SplitMix64) -> Model {
        let mut cells = BTreeMap::new();
        for r in 0..rows {
            for c in 0..cols {
                cells.insert((r, c), rng.below(3) == 0);
            }
        }
        let mut edges = BTreeSet::new();
        for r in 0..rows as i64 {
            for c in 0..cols as i64 {
                for dr in -1..=1i64 {
                    for dc in -1..=1i64 {
                        if (dr, dc) == (0, 0) {
                            continue;
                        }
                        let (nr, nc) = (r + dr, c + dc);
                        if nr >= 0 && nc >= 0 && nr < rows as i64 && nc < cols as i64 {
                            edges.insert((
                                (r as u32, c as u32),
                                (nr as u32, nc as u32),
                            ));
                        }
                    }
                }
            }
        }
        Model { cells, edges }
    }

    fn random_pos(&self, rng: &mut SplitMix64) -> Pos {
        let all: Vec<Pos> = self.cells.keys().copied().collect();
        all[rng.below(all.len())]
    }
}

/// Build a tools instance from a model; returns position -> vertex id.
fn build(tools: &mut SpiNNTools, model: &Model) -> BTreeMap<Pos, VertexId> {
    let mut ids = BTreeMap::new();
    for (pos, alive) in &model.cells {
        ids.insert(
            *pos,
            tools
                .add_machine_vertex(ConwayCellVertex::arc(pos.0, pos.1, *alive))
                .unwrap(),
        );
    }
    for (a, b) in &model.edges {
        tools.add_machine_edge(ids[a], ids[b], STATE_PARTITION).unwrap();
    }
    ids
}

/// One graph delta, applicable both to a live tools instance (the
/// incremental path) and to the model (the from-scratch reference).
enum Delta {
    AddVertex { pos: Pos, alive: bool, link_to: Pos },
    AddEdge { a: Pos, b: Pos },
    RemoveVertex { pos: Pos },
}

impl Delta {
    fn apply_to_model(&self, model: &mut Model) {
        match self {
            Delta::AddVertex { pos, alive, link_to } => {
                model.cells.insert(*pos, *alive);
                model.edges.insert((*pos, *link_to));
                model.edges.insert((*link_to, *pos));
            }
            Delta::AddEdge { a, b } => {
                model.edges.insert((*a, *b));
                model.edges.insert((*b, *a));
            }
            Delta::RemoveVertex { pos } => {
                model.cells.remove(pos);
                model.edges.retain(|(x, y)| x != pos && y != pos);
            }
        }
    }

    fn apply_to_tools(&self, tools: &mut SpiNNTools, ids: &mut BTreeMap<Pos, VertexId>) {
        match self {
            Delta::AddVertex { pos, alive, link_to } => {
                let id = tools
                    .add_machine_vertex(ConwayCellVertex::arc(pos.0, pos.1, *alive))
                    .unwrap();
                tools.add_machine_edge(id, ids[link_to], STATE_PARTITION).unwrap();
                tools.add_machine_edge(ids[link_to], id, STATE_PARTITION).unwrap();
                ids.insert(*pos, id);
            }
            Delta::AddEdge { a, b } => {
                tools.add_machine_edge(ids[a], ids[b], STATE_PARTITION).unwrap();
                tools.add_machine_edge(ids[b], ids[a], STATE_PARTITION).unwrap();
            }
            Delta::RemoveVertex { pos } => {
                let id = ids.remove(pos).unwrap();
                tools.remove_machine_vertex(id).unwrap();
            }
        }
    }
}

/// The property: for `delta`, at every pool width, incremental
/// recordings after `run(T1); mutate; run(T2)` equal a fresh build of
/// the final graph run for `T2`.
fn check_delta_equivalence(base: &Model, delta: Delta, t1: u64, t2: u64) {
    let mut final_model = base.clone();
    delta.apply_to_model(&mut final_model);

    for threads in [1usize, 2, 8] {
        // Incremental path.
        let mut inc = SpiNNTools::new(
            ToolsConfig::new(MachineSpec::Spinn3).with_mapping_threads(threads),
        )
        .unwrap();
        let mut inc_ids = build(&mut inc, base);
        inc.run_ticks(t1).unwrap();
        delta.apply_to_tools(&mut inc, &mut inc_ids);
        inc.run_ticks(t2).unwrap();
        let report = inc.remap_report().expect("reconcile must report").clone();
        assert!(
            report.stages_rerun < report.stage_count(),
            "threads={threads}: small delta re-ran every stage: {report:?}"
        );

        // From-scratch reference: the final graph, fresh.
        let mut fresh = SpiNNTools::new(
            ToolsConfig::new(MachineSpec::Spinn3).with_mapping_threads(threads),
        )
        .unwrap();
        let fresh_ids = build(&mut fresh, &final_model);
        fresh.run_ticks(t2).unwrap();

        for (pos, fid) in &fresh_ids {
            let f = fresh.recording(*fid);
            let i = inc.recording(inc_ids[pos]);
            assert_eq!(f.len() as u64, t2, "{pos:?}: wrong recording length");
            assert_eq!(
                f, i,
                "threads={threads}: cell {pos:?} diverged (incremental vs fresh)"
            );
        }
        // No survivor recordings for removed cells.
        for pos in base.cells.keys() {
            if !final_model.cells.contains_key(pos) {
                // The id map dropped it; nothing to check beyond the
                // fresh side not having it either.
                assert!(!fresh_ids.contains_key(pos));
            }
        }
    }
}

#[test]
fn e13_add_vertex_delta_matches_from_scratch() {
    prop::check(4, 0xADD__0001, |rng| {
        let base = Model::grid(4, 4, rng);
        let link_to = base.random_pos(rng);
        let delta = Delta::AddVertex {
            pos: (9, rng.below(4) as u32),
            alive: rng.below(2) == 0,
            link_to,
        };
        check_delta_equivalence(&base, delta, 2, 4);
    });
}

#[test]
fn e13_add_edge_delta_matches_from_scratch() {
    prop::check(4, 0xADD__ED6E, |rng| {
        let base = Model::grid(4, 4, rng);
        // Two distinct cells, possibly already adjacent — re-adding a
        // parallel edge is legal and changes the neighbour count.
        let a = base.random_pos(rng);
        let mut b = base.random_pos(rng);
        while b == a {
            b = base.random_pos(rng);
        }
        check_delta_equivalence(&base, Delta::AddEdge { a, b }, 2, 4);
    });
}

#[test]
fn e13_remove_vertex_delta_matches_from_scratch() {
    prop::check(4, 0x0DE1_E7E, |rng| {
        let base = Model::grid(4, 4, rng);
        let pos = base.random_pos(rng);
        check_delta_equivalence(&base, Delta::RemoveVertex { pos }, 2, 4);
    });
}

#[test]
fn e13_chained_deltas_stay_equivalent() {
    // Several reconciles in sequence against one instance: the stage
    // cache and journals must stay coherent across epochs.
    let mut rng = SplitMix64::new(0xC4A1);
    let mut model = Model::grid(4, 4, &mut rng);
    let mut tools = SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn3)).unwrap();
    let mut ids = build(&mut tools, &model);
    tools.run_ticks(2).unwrap();

    let deltas = [
        Delta::AddVertex { pos: (9, 0), alive: true, link_to: (0, 0) },
        Delta::RemoveVertex { pos: (2, 2) },
        Delta::AddEdge { a: (0, 0), b: (3, 3) },
    ];
    for (i, delta) in deltas.into_iter().enumerate() {
        delta.apply_to_model(&mut model);
        delta.apply_to_tools(&mut tools, &mut ids);
        tools.run_ticks(3).unwrap();

        let mut fresh = SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn3)).unwrap();
        let fresh_ids = build(&mut fresh, &model);
        fresh.run_ticks(3).unwrap();
        for (pos, fid) in &fresh_ids {
            assert_eq!(
                fresh.recording(*fid),
                tools.recording(ids[pos]),
                "epoch {i}: cell {pos:?} diverged"
            );
        }
        let report = tools.remap_report().unwrap();
        assert!(report.stages_rerun < report.stage_count(), "epoch {i}: {report:?}");
    }
}
