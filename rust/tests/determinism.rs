//! Satellite: the full `Mapping` output — placements, routing forest,
//! keys, tables, IP tags — is identical for worker-pool widths 1, 2 and
//! 8, on both of the paper's workload shapes (§7.1 Conway grid, §7.2
//! microcircuit), and repeated runs are stable. The engine path
//! (Figure 10, with sharded algorithms) must also match the direct path
//! byte-for-byte.

use spinntools::apps::networks::{conway_machine_graph, microcircuit_machine_graph};
use spinntools::graph::MachineGraph;
use spinntools::machine::{Machine, MachineBuilder};
use spinntools::mapping::{
    map_graph, map_graph_via_engine, Mapping, MappingConfig, MappingOptions,
};

/// Canonical text form of everything mapping produces; equal strings
/// mean equal mappings (every constituent is a deterministic
/// `BTreeMap`/`Vec` with derived `Debug`).
fn fingerprint(m: &Mapping) -> String {
    format!(
        "{:?}\n{:?}\n{:?}\n{:?}\n{:?}\n{:?}",
        m.placements, m.forest, m.keys, m.tables, m.iptags, m.reverse_iptags
    )
}

fn config(threads: usize) -> MappingConfig {
    MappingConfig {
        options: MappingOptions::with_threads(threads),
        ..Default::default()
    }
}

fn assert_thread_invariant(machine: &Machine, graph: &MachineGraph, label: &str) {
    let baseline = fingerprint(&map_graph(machine, graph, &config(1)).unwrap());
    // Repeated serial runs are stable.
    let again = fingerprint(&map_graph(machine, graph, &config(1)).unwrap());
    assert_eq!(baseline, again, "{label}: serial mapping not reproducible");
    for threads in [2usize, 8] {
        let sharded = fingerprint(&map_graph(machine, graph, &config(threads)).unwrap());
        assert_eq!(
            baseline, sharded,
            "{label}: mapping differs at {threads} threads"
        );
        // Repeated sharded runs are stable too.
        let sharded_again =
            fingerprint(&map_graph(machine, graph, &config(threads)).unwrap());
        assert_eq!(
            sharded, sharded_again,
            "{label}: {threads}-thread mapping not reproducible"
        );
    }
}

#[test]
fn conway_mapping_identical_at_1_2_8_threads() {
    let machine = MachineBuilder::spinn5().build();
    let graph = conway_machine_graph(16, 16, |r, c| (r + c) % 2 == 0);
    assert_thread_invariant(&machine, &graph, "conway 16x16 / spinn5");
}

#[test]
fn microcircuit_mapping_identical_at_1_2_8_threads() {
    let machine = MachineBuilder::boards(3).build();
    let graph = microcircuit_machine_graph(&machine, 0.05, 20260728).expect("split");
    assert!(graph.n_vertices() >= 16, "workload too small to exercise sharding");
    assert_thread_invariant(&machine, &graph, "microcircuit 5% / 3 boards");
}

#[test]
fn engine_path_matches_direct_byte_for_byte() {
    let machine = MachineBuilder::spinn5().build();
    let graph = conway_machine_graph(12, 12, |r, c| (r + c) % 2 == 0);
    for threads in [1usize, 2, 8] {
        let direct = map_graph(&machine, &graph, &config(threads)).unwrap();
        let (engine, workflow) =
            map_graph_via_engine(&machine, &graph, &config(threads)).unwrap();
        assert_eq!(
            fingerprint(&direct),
            fingerprint(&engine),
            "engine and direct mappings diverge at {threads} threads"
        );
        // The engine actually ran the sharded stages.
        assert!(workflow.0.contains(&"ner_router".to_string()));
        assert!(workflow.0.contains(&"table_compressor".to_string()));
    }
}
