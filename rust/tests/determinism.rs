//! Satellite: the full `Mapping` output — placements, routing forest,
//! keys, tables, IP tags — is identical for worker-pool widths 1, 2 and
//! 8, on both of the paper's workload shapes (§7.1 Conway grid, §7.2
//! microcircuit), and repeated runs are stable. The engine path
//! (Figure 10, with sharded algorithms) must also match the direct path
//! byte-for-byte.

use spinntools::apps::networks::{conway_machine_graph, microcircuit_machine_graph};
use spinntools::graph::MachineGraph;
use spinntools::machine::{ChipCoord, Machine, MachineBuilder, ALL_DIRECTIONS};
use spinntools::mapping::{
    map_graph, map_graph_via_engine, router, Mapping, MappingConfig, MappingOptions,
};
use spinntools::util::prop;

/// Canonical text form of everything mapping produces; equal strings
/// mean equal mappings (every constituent is a deterministic
/// `BTreeMap`/`Vec` with derived `Debug`).
fn fingerprint(m: &Mapping) -> String {
    format!(
        "{:?}\n{:?}\n{:?}\n{:?}\n{:?}\n{:?}",
        m.placements, m.forest, m.keys, m.tables, m.iptags, m.reverse_iptags
    )
}

fn config(threads: usize) -> MappingConfig {
    MappingConfig {
        options: MappingOptions::with_threads(threads),
        ..Default::default()
    }
}

fn assert_thread_invariant(machine: &Machine, graph: &MachineGraph, label: &str) {
    let baseline = fingerprint(&map_graph(machine, graph, &config(1)).unwrap());
    // Repeated serial runs are stable.
    let again = fingerprint(&map_graph(machine, graph, &config(1)).unwrap());
    assert_eq!(baseline, again, "{label}: serial mapping not reproducible");
    for threads in [2usize, 8] {
        let sharded = fingerprint(&map_graph(machine, graph, &config(threads)).unwrap());
        assert_eq!(
            baseline, sharded,
            "{label}: mapping differs at {threads} threads"
        );
        // Repeated sharded runs are stable too.
        let sharded_again =
            fingerprint(&map_graph(machine, graph, &config(threads)).unwrap());
        assert_eq!(
            sharded, sharded_again,
            "{label}: {threads}-thread mapping not reproducible"
        );
    }
}

#[test]
fn conway_mapping_identical_at_1_2_8_threads() {
    let machine = MachineBuilder::spinn5().build();
    let graph = conway_machine_graph(16, 16, |r, c| (r + c) % 2 == 0);
    assert_thread_invariant(&machine, &graph, "conway 16x16 / spinn5");
}

#[test]
fn microcircuit_mapping_identical_at_1_2_8_threads() {
    let machine = MachineBuilder::boards(3).build();
    let graph = microcircuit_machine_graph(&machine, 0.05, 20260728).expect("split");
    assert!(graph.n_vertices() >= 16, "workload too small to exercise sharding");
    assert_thread_invariant(&machine, &graph, "microcircuit 5% / 3 boards");
}

/// Satellite (chaos PR): random boot-time fault sets — dead chips, dead
/// cores, dead links — on the big Conway workload. The mapping must (a)
/// never place a vertex on a dead resource, (b) never route a tree over
/// a dead link or through a dead chip, and (c) stay byte-identical
/// across worker-pool widths 1/2/8. Debug builds run the 20x20 grid on
/// one SpiNN-5 board; release builds (CI runs `cargo test --release`)
/// run the bench-shaped 88x88 grid on the 576-chip machine.
#[test]
fn mapping_with_random_boot_faults_is_sound_and_thread_invariant() {
    let (rows, cases) = if cfg!(debug_assertions) { (20u32, 3u32) } else { (88u32, 2u32) };
    prop::check(cases, 0xFA07, |rng| {
        let mut builder = if cfg!(debug_assertions) {
            MachineBuilder::spinn5()
        } else {
            MachineBuilder::boards(12)
        };
        let template = if cfg!(debug_assertions) {
            MachineBuilder::spinn5().build()
        } else {
            MachineBuilder::boards(12).build()
        };
        let (w, h) = (template.width as usize, template.height as usize);
        // Random chips to kill: real, non-Ethernet, not the boot chip.
        let mut dead_chips: Vec<ChipCoord> = Vec::new();
        for _ in 0..rng.below(3) {
            let c = (rng.below(w) as u32, rng.below(h) as u32);
            let eligible = template
                .chip(c)
                .map(|ch| !ch.is_ethernet() && !ch.is_virtual)
                .unwrap_or(false)
                && c != (0, 0);
            if eligible && !dead_chips.contains(&c) {
                builder = builder.dead_chip(c);
                dead_chips.push(c);
            }
        }
        // Random dead cores and links.
        for _ in 0..1 + rng.below(4) {
            let c = (rng.below(w) as u32, rng.below(h) as u32);
            builder = builder.dead_core(c, 1 + rng.below(16) as u8);
        }
        for _ in 0..1 + rng.below(5) {
            let c = (rng.below(w) as u32, rng.below(h) as u32);
            builder = builder.dead_link(c, ALL_DIRECTIONS[rng.below(6)]);
        }
        let machine = builder.build();
        let graph = conway_machine_graph(rows, rows, |r, c| (r + c) % 3 == 0);
        let baseline = match map_graph(&machine, &graph, &config(1)) {
            Ok(m) => m,
            // Random faults can isolate a target; that is the router's
            // error to raise, not a mapping to verify.
            Err(_) => return,
        };
        // (a) placements only on live resources.
        for (_, loc) in baseline.placements.iter() {
            let chip = machine
                .chip(loc.chip())
                .unwrap_or_else(|| panic!("vertex placed on dead chip {:?}", loc.chip()));
            assert!(
                chip.processor(loc.p).is_some(),
                "vertex placed on dead core {loc}"
            );
        }
        // (b) every tree walks only working links (tree_valid re-walks
        // each hop against the machine's live link table).
        for (key, tree) in &baseline.forest.trees {
            assert!(
                router::tree_valid(tree, &machine, &Default::default()),
                "tree {key:?} traverses a dead resource"
            );
            for chip in tree.nodes.keys() {
                assert!(!dead_chips.contains(chip), "tree {key:?} crosses dead chip {chip:?}");
            }
        }
        // (c) pool-width invariance on the faulted machine.
        let base_fp = fingerprint(&baseline);
        for threads in [2usize, 8] {
            let sharded = fingerprint(&map_graph(&machine, &graph, &config(threads)).unwrap());
            assert_eq!(base_fp, sharded, "faulted-machine mapping differs at {threads} threads");
        }
    });
}

#[test]
fn engine_path_matches_direct_byte_for_byte() {
    let machine = MachineBuilder::spinn5().build();
    let graph = conway_machine_graph(12, 12, |r, c| (r + c) % 2 == 0);
    for threads in [1usize, 2, 8] {
        let direct = map_graph(&machine, &graph, &config(threads)).unwrap();
        let (engine, workflow) =
            map_graph_via_engine(&machine, &graph, &config(threads)).unwrap();
        assert_eq!(
            fingerprint(&direct),
            fingerprint(&engine),
            "engine and direct mappings diverge at {threads} threads"
        );
        // The engine actually ran the sharded stages.
        assert!(workflow.0.contains(&"ner_router".to_string()));
        assert!(workflow.0.contains(&"table_compressor".to_string()));
    }
}
