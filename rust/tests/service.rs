//! Multi-tenant machine service property suite (DESIGN.md §11,
//! experiment E17).
//!
//! The core property: a machine partitioned among N concurrent tenants
//! is **observationally private** — every tenant's recordings are
//! byte-identical to the same job run alone on a machine of its own,
//! no two tenants' placements, multicast key windows, or IP-tag slots
//! ever overlap, and a fault (even a whole-board death) inside one
//! tenant's partition never perturbs another tenant's results.
//!
//! Also pinned here:
//! - a single-tenant service is byte-identical to the direct
//!   [`SpiNNTools`] path, over both the SCAMP and the data-plane
//!   load/extraction methods (the per-tenant port windows collapse to
//!   the defaults for job 0);
//! - admission is strict FIFO with head-of-line blocking (a small job
//!   never overtakes a blocked big one), freed boards are reused, and
//!   boards that die under a tenant are retired;
//! - a board death evicts its tenant via the newest checkpoint and the
//!   job resumes from the snapshot — not from tick 0 — in a fresh
//!   partition.
//!
//! CI runs this suite under a fixed seed matrix via `SERVICE_SEED`,
//! and re-runs it over an unreliable wire in the combined
//! `WIRE_FAULTS=1` row.

use std::collections::{BTreeMap, BTreeSet};

use spinntools::apps::conway::{ConwayCellVertex, STATE_PARTITION};
use spinntools::front::{
    CheckpointConfig, ExtractionMethod, HealPolicy, LifecycleEvent, LoadMethod, MachineService,
    MachineSpec, SpiNNTools, SupervisorConfig, ToolsConfig,
};
use spinntools::graph::VertexId;
use spinntools::machine::ChipCoord;
use spinntools::simulator::{ChaosPlan, Fault, WireFaults};

const TICKS: u64 = 6;
const QUANTUM: u64 = 2;

/// Base seed for the tenant mix; CI sweeps a matrix of these.
fn base_seed() -> u64 {
    std::env::var("SERVICE_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5E81)
}

/// CI's combined matrix row re-runs this whole suite over an unreliable
/// wire (`WIRE_FAULTS=1`, seeded by `WIRE_SEED`): every quantum, sweep,
/// checkpoint and resume crosses the faulty link, and every isolation
/// assertion must hold unchanged.
fn env_wire(config: ToolsConfig) -> ToolsConfig {
    let on = std::env::var("WIRE_FAULTS").map(|v| v != "0" && !v.is_empty()).unwrap_or(false);
    if !on {
        return config;
    }
    let seed = std::env::var("WIRE_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x31E5);
    config.with_wire_faults(WireFaults::from_seed(seed))
}

fn supervised() -> SupervisorConfig {
    SupervisorConfig { poll_interval_ticks: 1, policy: HealPolicy::Remap, max_heals: 4 }
}

fn every_tick() -> CheckpointConfig {
    CheckpointConfig { interval_ticks: 1, keep: 2 }
}

/// A seeded `rows x cols` Conway grid as a job-builder closure: the
/// same closure shape [`MachineService::submit`] takes, reusable for
/// building the solo oracle.
fn grid(
    rows: u32,
    cols: u32,
    seed: u64,
) -> impl FnOnce(&mut SpiNNTools) -> anyhow::Result<Vec<VertexId>> {
    move |tools| {
        let alive =
            |r: u32, c: u32| (r.wrapping_mul(31) ^ c.wrapping_mul(17) ^ seed as u32) % 3 == 0;
        let mut ids = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                ids.push(tools.add_machine_vertex(ConwayCellVertex::arc(r, c, alive(r, c)))?);
            }
        }
        let idx = |r: i64, c: i64| -> Option<usize> {
            (r >= 0 && c >= 0 && r < rows as i64 && c < cols as i64)
                .then_some((r * cols as i64 + c) as usize)
        };
        for r in 0..rows as i64 {
            for c in 0..cols as i64 {
                for dr in -1..=1 {
                    for dc in -1..=1 {
                        if (dr, dc) == (0, 0) {
                            continue;
                        }
                        if let Some(n) = idx(r + dr, c + dc) {
                            tools.add_machine_edge(ids[idx(r, c).unwrap()], ids[n], STATE_PARTITION)?;
                        }
                    }
                }
            }
        }
        Ok(ids)
    }
}

/// The seeded tenant mix: job `i`'s grid shape and pattern seed.
fn mix(i: u64) -> (u32, u32, u64) {
    let s = base_seed()
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(i.wrapping_mul(0xA24B_AED4_963E_E407));
    (3 + (s % 3) as u32, 3 + ((s >> 8) % 3) as u32, s)
}

/// The oracle: the same job run alone, one uninterrupted `run_ticks`,
/// on a machine of its own.
fn solo_run(rows: u32, cols: u32, seed: u64, config: ToolsConfig) -> Vec<Vec<u8>> {
    let mut tools = SpiNNTools::new(env_wire(config)).unwrap();
    let ids = grid(rows, cols, seed)(&mut tools).unwrap();
    tools.run_ticks(TICKS).unwrap();
    ids.iter().map(|v| tools.recording(*v).to_vec()).collect()
}

fn service_recordings(svc: &MachineService, id: u64) -> Vec<Vec<u8>> {
    svc.vertices(id)
        .to_vec()
        .iter()
        .map(|v| svc.recording(id, *v).to_vec())
        .collect()
}

#[test]
fn single_tenant_service_matches_direct_path() {
    // Satellite regression: with one tenant, the service must be a
    // transparent wrapper — job 0's key window starts at 0 and its port
    // window is the configured base, so nothing observable differs.
    let seed = base_seed();
    for (load, extract) in [
        (LoadMethod::Scamp, ExtractionMethod::Scamp),
        (LoadMethod::FastMulticast, ExtractionMethod::FastMulticast),
    ] {
        let config = || {
            env_wire(
                ToolsConfig::new(MachineSpec::Spinn5)
                    .with_loading(load)
                    .with_extraction(extract),
            )
        };
        let mut tools = SpiNNTools::new(config()).unwrap();
        let ids = grid(6, 6, seed)(&mut tools).unwrap();
        tools.run_ticks(TICKS).unwrap();
        let direct: Vec<Vec<u8>> = ids.iter().map(|v| tools.recording(*v).to_vec()).collect();

        let mut svc = MachineService::new(config(), 3).unwrap(); // two quanta
        let id = svc.submit("only", 1, TICKS, grid(6, 6, seed)).unwrap();
        svc.run_to_completion().unwrap();
        assert!(svc.is_finished(id));
        assert_eq!(
            service_recordings(&svc, id),
            direct,
            "single-tenant service diverged from the direct path ({load:?}/{extract:?})"
        );
    }
}

#[test]
fn tenants_match_solo_runs_at_all_widths() {
    // E17 core property (a): each of three concurrent tenants is
    // byte-identical to its solo run — at mapping pool widths 1, 2, 8.
    for threads in [1usize, 2, 8] {
        let template =
            env_wire(ToolsConfig::new(MachineSpec::Boards(3)).with_mapping_threads(threads));
        let mut svc = MachineService::new(template, QUANTUM).unwrap();
        let mut jobs = Vec::new();
        for i in 0..3u64 {
            let (r, c, s) = mix(i);
            jobs.push((svc.submit(&format!("t{i}"), 1, TICKS, grid(r, c, s)).unwrap(), r, c, s));
        }
        svc.run_to_completion().unwrap();
        let report = svc.report();
        assert!(report.key_windows_disjoint());
        assert_eq!(report.boards_retired, 0);
        for (id, r, c, s) in jobs {
            assert!(svc.is_finished(id), "threads {threads}: job {id} unfinished");
            let solo =
                solo_run(r, c, s, ToolsConfig::virtual_spinn5(1).with_mapping_threads(threads));
            assert_eq!(
                service_recordings(&svc, id),
                solo,
                "threads {threads}: tenant {id} diverged from its solo run"
            );
        }
    }
}

#[test]
fn key_windows_and_placements_never_overlap() {
    // E17 core property (b): with all three tenants admitted and
    // mapped, no chip, multicast key, or IP-tag slot is shared.
    let template = env_wire(ToolsConfig::new(MachineSpec::Boards(3)));
    let mut svc = MachineService::new(template, QUANTUM).unwrap();
    let mut ids = Vec::new();
    for i in 0..3u64 {
        let (r, c, s) = mix(i);
        ids.push(svc.submit(&format!("t{i}"), 1, TICKS, grid(r, c, s)).unwrap());
    }
    svc.tick_round().unwrap();
    let machine = MachineSpec::Boards(3).template();
    let report = svc.report();
    assert!(report.key_windows_disjoint());
    let mut chip_owner: BTreeMap<ChipCoord, u64> = BTreeMap::new();
    let mut tag_owner: BTreeMap<(ChipCoord, u8), u64> = BTreeMap::new();
    for &id in &ids {
        let boards: BTreeSet<ChipCoord> = svc.boards_of(id).iter().copied().collect();
        assert!(!boards.is_empty(), "job {id} not admitted in round 1");
        let session = svc.session(id).unwrap();
        let mapping = session.mapping().expect("mapped after the first quantum");
        let window = report.tenants[id as usize].key_space;
        for v in svc.vertices(id) {
            let chip = mapping.placement(*v).expect("placed").chip();
            assert_eq!(
                machine.nearest_ethernet(chip).map(|e| boards.contains(&e)),
                Some(true),
                "job {id}: vertex placed off-partition at {chip:?}"
            );
            if let Some(prev) = chip_owner.insert(chip, id) {
                assert_eq!(prev, id, "chip {chip:?} shared between tenants");
            }
        }
        for kr in mapping.keys.values() {
            let base = kr.base as u64;
            assert!(
                base >= window.0 && base + kr.n_keys() <= window.1,
                "job {id}: key block {base:#x}(+{}) outside window {window:x?}",
                kr.n_keys()
            );
        }
        for tag in mapping.iptags.values() {
            assert!(boards.contains(&tag.board), "job {id}: IP tag on a foreign board");
            if let Some(prev) = tag_owner.insert((tag.board, tag.tag), id) {
                assert_eq!(prev, id, "IP tag slot shared between tenants");
            }
        }
        for tag in mapping.reverse_iptags.values() {
            assert!(boards.contains(&tag.board), "job {id}: reverse tag on a foreign board");
        }
    }
    for (i, &a) in ids.iter().enumerate() {
        let ba: BTreeSet<ChipCoord> = svc.boards_of(a).iter().copied().collect();
        for &b in &ids[i + 1..] {
            assert!(
                svc.boards_of(b).iter().all(|x| !ba.contains(x)),
                "jobs {a} and {b} share a board"
            );
        }
    }
    svc.run_to_completion().unwrap();
}

#[test]
fn queue_is_fifo_and_freed_boards_are_reused() {
    // E17 core property (d): strict FIFO with head-of-line blocking,
    // freed partitions coalesce back into the pool and are re-carved.
    let (r0, c0, s0) = mix(30);
    let (r1, c1, s1) = mix(31);
    let (r2, c2, s2) = mix(32);
    let template = env_wire(ToolsConfig::new(MachineSpec::Boards(3)));
    let mut svc = MachineService::new(template, QUANTUM).unwrap();
    let a = svc.submit("a", 2, TICKS, grid(r0, c0, s0)).unwrap();
    let b = svc.submit("b", 2, TICKS, grid(r1, c1, s1)).unwrap();
    let c = svc.submit("c", 1, TICKS, grid(r2, c2, s2)).unwrap();
    svc.tick_round().unwrap();
    // a holds 2 of the 3 boards. b (the head) needs 2 and blocks; c
    // would fit the one free board but must not overtake the head.
    assert_eq!(svc.boards_of(a).len(), 2);
    assert!(svc.boards_of(b).is_empty());
    assert!(svc.boards_of(c).is_empty(), "c overtook the blocked head of the queue");
    assert_eq!(svc.queue_len(), 2);
    let a_boards: BTreeSet<ChipCoord> = svc.boards_of(a).iter().copied().collect();

    svc.run_to_completion().unwrap();
    for id in [a, b, c] {
        assert!(svc.is_finished(id));
    }
    let admitted: Vec<&str> = svc
        .lifecycle()
        .events()
        .iter()
        .filter_map(|e| match e {
            LifecycleEvent::Admitted { tenant, .. } => Some(tenant.as_str()),
            _ => None,
        })
        .collect();
    assert_eq!(admitted, ["a", "b", "c"], "admission order must be submission order");
    assert!(
        svc.boards_of(b).iter().any(|x| a_boards.contains(x)),
        "b never reused a's freed boards"
    );
    let report = svc.report();
    assert_eq!(report.tenants[a as usize].queue_rounds, 0);
    assert!(report.tenants[b as usize].queue_rounds >= 1, "b never waited: {report:?}");
    assert!(report.key_windows_disjoint());
    assert_eq!(report.boards_retired, 0);
    // Queueing and board reuse are invisible in the results.
    assert_eq!(service_recordings(&svc, a), solo_run(r0, c0, s0, ToolsConfig::virtual_spinn5(2)));
    assert_eq!(service_recordings(&svc, b), solo_run(r1, c1, s1, ToolsConfig::virtual_spinn5(2)));
    assert_eq!(service_recordings(&svc, c), solo_run(r2, c2, s2, ToolsConfig::virtual_spinn5(1)));
}

#[test]
fn chaos_in_one_tenant_never_perturbs_another() {
    // E17 core property (c), healable flavour: a chip death inside a's
    // partition self-heals *within* the partition; a still matches its
    // solo run, and b never notices.
    let (ra, ca, sa) = mix(10);
    let (rb, cb, sb) = mix(11);
    let template = env_wire(
        ToolsConfig::new(MachineSpec::Boards(3))
            .with_supervision(supervised())
            .with_checkpoint(every_tick()),
    );
    let mut svc = MachineService::new(template, QUANTUM).unwrap();
    let a = svc.submit("a", 1, TICKS, grid(ra, ca, sa)).unwrap();
    let b = svc.submit("b", 1, TICKS, grid(rb, cb, sb)).unwrap();
    svc.tick_round().unwrap();
    // A used, killable (non-Ethernet) chip inside a's partition.
    let machine = MachineSpec::Boards(3).template();
    let mapping = svc.session(a).unwrap().mapping().unwrap();
    let chip = svc
        .vertices(a)
        .iter()
        .map(|v| mapping.placement(*v).unwrap().chip())
        .find(|c| !machine.chip(*c).map(|ch| ch.is_ethernet()).unwrap_or(true))
        .expect("tenant a uses a killable chip");
    svc.inject_chaos(a, ChaosPlan::new().with(3, Fault::ChipDeath(chip))).unwrap();
    svc.run_to_completion().unwrap();
    assert!(svc.is_finished(a) && svc.is_finished(b));

    let solo_cfg = || {
        ToolsConfig::virtual_spinn5(1)
            .with_supervision(supervised())
            .with_checkpoint(every_tick())
    };
    assert_eq!(
        service_recordings(&svc, a),
        solo_run(ra, ca, sa, solo_cfg()),
        "tenant a's healed run diverged from its solo run"
    );
    assert_eq!(
        service_recordings(&svc, b),
        solo_run(rb, cb, sb, solo_cfg()),
        "chaos in tenant a perturbed tenant b"
    );
    let healed: Vec<&str> = svc
        .lifecycle()
        .events()
        .iter()
        .filter_map(|e| match e {
            LifecycleEvent::Healed { tenant, .. } => Some(tenant.as_str()),
            _ => None,
        })
        .collect();
    assert!(healed.contains(&"a"), "a's heal never surfaced: {healed:?}");
    assert!(!healed.contains(&"b"), "b healed without a fault");
    let report = svc.report();
    assert!(report.tenants[a as usize].heals >= 1);
    assert_eq!(report.tenants[b as usize].heals, 0);
    assert_eq!(report.tenants[a as usize].evictions, 0, "an in-partition heal is not an eviction");
}

#[test]
fn board_death_evicts_suspends_and_resumes_elsewhere() {
    // E17 core property (c), unhealable flavour: killing a's Ethernet
    // chip takes its whole board (and host link) down — nothing inside
    // the partition is left to heal onto. The service must evict a via
    // its newest checkpoint, retire the board, re-admit a onto the
    // spare board, and resume from the snapshot; b never notices.
    let (ra, ca, sa) = mix(20);
    let (rb, cb, sb) = mix(21);
    let template = env_wire(
        ToolsConfig::new(MachineSpec::Boards(3))
            .with_supervision(supervised())
            .with_checkpoint(every_tick()),
    );
    let mut svc = MachineService::new(template, QUANTUM).unwrap();
    let a = svc.submit("a", 1, TICKS, grid(ra, ca, sa)).unwrap();
    let b = svc.submit("b", 1, TICKS, grid(rb, cb, sb)).unwrap();
    svc.tick_round().unwrap();
    let doomed = svc.boards_of(a)[0];
    svc.inject_chaos(a, ChaosPlan::new().with(3, Fault::ChipDeath(doomed))).unwrap();
    svc.run_to_completion().unwrap();
    assert!(svc.is_finished(a), "a must finish after eviction + resume");
    assert!(svc.is_finished(b));
    assert_ne!(svc.boards_of(a), [doomed], "a finished on a fresh board");

    let solo_cfg = || {
        ToolsConfig::virtual_spinn5(1)
            .with_supervision(supervised())
            .with_checkpoint(every_tick())
    };
    assert_eq!(
        service_recordings(&svc, a),
        solo_run(ra, ca, sa, solo_cfg()),
        "evicted + resumed tenant diverged from its solo run"
    );
    assert_eq!(
        service_recordings(&svc, b),
        solo_run(rb, cb, sb, solo_cfg()),
        "a's board death perturbed tenant b"
    );
    let of_a = svc.lifecycle().of_tenant("a");
    assert!(
        of_a.iter().any(|e| matches!(e, LifecycleEvent::Evicted { .. })),
        "no eviction surfaced: {of_a:?}"
    );
    assert!(
        of_a.iter()
            .any(|e| matches!(e, LifecycleEvent::Resumed { from_tick, .. } if *from_tick >= 1)),
        "resume must come from a snapshot, not tick 0: {of_a:?}"
    );
    let report = svc.report();
    assert_eq!(report.boards_retired, 1, "the dead board must be retired");
    assert_eq!(report.tenants[a as usize].evictions, 1);
    assert_eq!(report.tenants[b as usize].evictions, 0);
    assert!(report.key_windows_disjoint());
}
