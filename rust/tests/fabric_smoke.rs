//! E11 satellite: `fabric-smoke` — run the `benches/fabric.rs`
//! workloads at tiny scale under `cargo test`, through the very same
//! probe harness (`front::fabric_probe`), so the bench code paths are
//! exercised on every test run and cannot rot.

use spinntools::front::fabric_probe::{run_fabric_probe, ProbeWorkload};
use spinntools::simulator::FabricMode;

fn smoke(workload: ProbeWorkload, ticks: u64) {
    let fast = run_fabric_probe(workload, ticks, FabricMode::Fast).unwrap();
    let legacy = run_fabric_probe(workload, ticks, FabricMode::Legacy).unwrap();
    assert_eq!(fast.ticks, ticks);
    assert!(fast.wall_seconds > 0.0);
    assert!(fast.events > 0, "{}: no events simulated", fast.workload);
    assert!(fast.mc_sent > 0, "{}: no packets sent", fast.workload);
    assert!(fast.hops > 0, "{}: no packets routed", fast.workload);
    // Tiny-scale equivalence rides along for free.
    assert_eq!(
        fast.digest, legacy.digest,
        "{}: fabrics diverged at smoke scale",
        fast.workload
    );
    // The JSON serialisation the bench writes must stay well-formed.
    let json = fast.to_json();
    assert_eq!(
        json.get("mode").and_then(|j| j.as_str()),
        Some("fast"),
        "probe JSON lost its mode field"
    );
    assert!(json.get("hops_per_sec").and_then(|j| j.as_f64()).unwrap() > 0.0);
}

#[test]
fn fabric_smoke_conway() {
    smoke(ProbeWorkload::Conway { side: 8, boards: 1 }, 4);
}

#[test]
fn fabric_smoke_microcircuit_storm() {
    smoke(ProbeWorkload::MicrocircuitStorm { scale: 0.02, boards: 1 }, 4);
}
