//! Checkpoint/restore property suite (DESIGN.md §9, experiment E15).
//!
//! The core property: a run that is snapshotted at tick `k`, torn down,
//! rebuilt in a fresh [`SpiNNTools`] instance and resumed from the
//! snapshot produces recordings **byte-identical** to the uninterrupted
//! run — at mapping worker-pool widths 1, 2 and 8, and both with and
//! without a fault injected after `k`. With a fault, the healed run
//! must also report which snapshot it restored from, and still match a
//! fresh run on the equivalently boot-degraded machine (the same
//! oracle as the chaos suite, now with only the tail replayed).
//!
//! Regressions pinned here:
//! - a chaos event landing exactly on a poll boundary belongs to the
//!   *next* chunk, so the boundary poll (and any snapshot captured at
//!   it) still sees a pre-fault machine;
//! - `reconcile()` preserves pre-mutation recordings when checkpointing
//!   is on, and surfaces the discard as a provenance anomaly when off;
//! - a heal during a *resumed* run covers the base ticks of earlier
//!   `run_ticks` calls.
//!
//! CI runs this suite under a fixed seed matrix via `CHAOS_SEED`.

use std::collections::BTreeSet;

use spinntools::apps::conway::{ConwayCellVertex, STATE_PARTITION};
use spinntools::front::{
    BootFaults, CheckpointConfig, Checkpointer, FileCheckpointer, HealPolicy, MachineSpec,
    RunSnapshot, SpiNNTools, SupervisorConfig, ToolsConfig,
};
use spinntools::graph::VertexId;
use spinntools::machine::ChipCoord;
use spinntools::simulator::{ChaosPlan, Fault, WireFaults};

const ROWS: u32 = 6;
const COLS: u32 = 6;
const TICKS: u64 = 6;

/// Base seed for the grid pattern; CI sweeps a matrix of these.
fn base_seed() -> u64 {
    std::env::var("CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0A5)
}

/// CI's combined matrix row re-runs this whole suite over an unreliable
/// wire (`WIRE_FAULTS=1`, seeded by `WIRE_SEED`): snapshot capture,
/// restore and the healed tail replay all cross the faulty link, and
/// every byte-identity assertion must hold unchanged.
fn env_wire(config: ToolsConfig) -> ToolsConfig {
    let on = std::env::var("WIRE_FAULTS").map(|v| v != "0" && !v.is_empty()).unwrap_or(false);
    if !on {
        return config;
    }
    let seed = std::env::var("WIRE_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x31E5);
    config.with_wire_faults(WireFaults::from_seed(seed))
}

fn supervised() -> SupervisorConfig {
    SupervisorConfig { poll_interval_ticks: 1, policy: HealPolicy::Remap, max_heals: 4 }
}

fn every_tick() -> CheckpointConfig {
    CheckpointConfig { interval_ticks: 1, keep: 2 }
}

/// Build the ROWS x COLS Conway grid into `tools`; returns vertex ids.
fn build_grid(tools: &mut SpiNNTools, seed: u64) -> Vec<VertexId> {
    let alive = |r: u32, c: u32| (r.wrapping_mul(31) ^ c.wrapping_mul(17) ^ seed as u32) % 3 == 0;
    let mut ids = Vec::new();
    for r in 0..ROWS {
        for c in 0..COLS {
            ids.push(
                tools
                    .add_machine_vertex(ConwayCellVertex::arc(r, c, alive(r, c)))
                    .unwrap(),
            );
        }
    }
    let idx = |r: i64, c: i64| -> Option<usize> {
        (r >= 0 && c >= 0 && r < ROWS as i64 && c < COLS as i64)
            .then_some((r * COLS as i64 + c) as usize)
    };
    for r in 0..ROWS as i64 {
        for c in 0..COLS as i64 {
            for dr in -1..=1 {
                for dc in -1..=1 {
                    if (dr, dc) == (0, 0) {
                        continue;
                    }
                    if let Some(n) = idx(r + dr, c + dc) {
                        tools
                            .add_machine_edge(ids[idx(r, c).unwrap()], ids[n], STATE_PARTITION)
                            .unwrap();
                    }
                }
            }
        }
    }
    ids
}

fn recordings(tools: &SpiNNTools, ids: &[VertexId]) -> Vec<Vec<u8>> {
    ids.iter().map(|v| tools.recording(*v).to_vec()).collect()
}

/// The uninterrupted reference: no checkpointing, one `run_ticks`.
fn plain_run(seed: u64, threads: usize) -> Vec<Vec<u8>> {
    let mut tools = SpiNNTools::new(env_wire(
        ToolsConfig::new(MachineSpec::Spinn5).with_mapping_threads(threads),
    ))
    .unwrap();
    let ids = build_grid(&mut tools, seed);
    tools.run_ticks(TICKS).unwrap();
    recordings(&tools, &ids)
}

/// The same workload on the equivalently boot-degraded machine.
fn degraded_run(seed: u64, faults: &BootFaults) -> Vec<Vec<u8>> {
    let mut tools = SpiNNTools::new(env_wire(
        ToolsConfig::new(MachineSpec::Spinn5)
            .with_supervision(supervised())
            .with_boot_faults(faults.clone()),
    ))
    .unwrap();
    let ids = build_grid(&mut tools, seed);
    tools.run_ticks(TICKS).unwrap();
    assert!(tools.heal_reports().is_empty(), "boot-degraded run must not heal");
    recordings(&tools, &ids)
}

/// A used, killable (non-Ethernet) chip of this workload's deterministic
/// placement — the target for every injected chip death below.
fn killable_used_chip(seed: u64) -> ChipCoord {
    let mut probe = SpiNNTools::new(env_wire(ToolsConfig::new(MachineSpec::Spinn5))).unwrap();
    let ids = build_grid(&mut probe, seed);
    probe.run_ticks(1).unwrap();
    let mapping = probe.mapping().unwrap();
    let machine = MachineSpec::Spinn5.template();
    let used: BTreeSet<ChipCoord> = ids
        .iter()
        .map(|v| mapping.placement(*v).unwrap().chip())
        .collect();
    used.into_iter()
        .find(|c| !machine.chip(*c).map(|ch| ch.is_ethernet()).unwrap_or(true))
        .expect("workload uses a killable chip")
}

#[test]
fn checkpointing_is_observation_only() {
    // Captures ride chunk boundaries; chunking must not perturb the
    // simulation, so a checkpointed run equals the plain run exactly.
    let seed = base_seed();
    let reference = plain_run(seed, 1);
    for interval in [1u64, 2, 5] {
        let mut tools = SpiNNTools::new(env_wire(
            ToolsConfig::new(MachineSpec::Spinn5)
                .with_checkpoint(CheckpointConfig { interval_ticks: interval, keep: 2 }),
        ))
        .unwrap();
        let ids = build_grid(&mut tools, seed);
        tools.run_ticks(TICKS).unwrap();
        assert_eq!(
            recordings(&tools, &ids),
            reference,
            "checkpoint interval {interval} changed the simulation"
        );
        let ticks = tools.checkpointer().expect("store auto-created").snapshot_ticks();
        assert!(!ticks.is_empty(), "no snapshot captured at interval {interval}");
        assert!(ticks.len() <= 2, "prune must respect keep=2: {ticks:?}");
    }
}

#[test]
fn suspend_resume_matches_uninterrupted_run() {
    // E15 core property, clean half: snapshot at tick k, rebuild in a
    // fresh instance, resume, run to the end — byte-identical to the
    // uninterrupted run, at every pool width. The snapshot crosses the
    // "process boundary" through its serialized form.
    let seed = base_seed();
    for threads in [1usize, 2, 8] {
        let reference = plain_run(seed, threads);
        for k in [1u64, 3, 5] {
            let snap_bytes = {
                let mut tools = SpiNNTools::new(env_wire(
                    ToolsConfig::new(MachineSpec::Spinn5)
                        .with_mapping_threads(threads)
                        .with_checkpoint(every_tick()),
                ))
                .unwrap();
                build_grid(&mut tools, seed);
                tools.run_ticks(k).unwrap();
                tools.suspend().unwrap().to_bytes()
            };
            let snap = RunSnapshot::from_bytes(&snap_bytes).unwrap();
            assert_eq!(snap.tick, k);

            let mut tools = SpiNNTools::new(env_wire(
                ToolsConfig::new(MachineSpec::Spinn5)
                    .with_mapping_threads(threads)
                    .with_checkpoint(every_tick()),
            ))
            .unwrap();
            let ids = build_grid(&mut tools, seed);
            tools.resume_from(&snap).unwrap();
            assert_eq!(tools.ticks_done(), k);
            tools.run_ticks(TICKS - k).unwrap();
            assert_eq!(
                recordings(&tools, &ids),
                reference,
                "resume at k={k}, threads {threads} diverged"
            );
        }
    }
}

#[test]
fn suspend_resume_then_fault_matches_degraded_run() {
    // E15 core property, faulty half: resume from tick k, then lose a
    // chip at tick k+1. The healed tail must restore from a snapshot
    // (not replay from 0) and still match the boot-degraded oracle.
    let seed = base_seed();
    let chip = killable_used_chip(seed);
    let reference = degraded_run(seed, &BootFaults { chips: vec![chip], ..Default::default() });
    for threads in [1usize, 2, 8] {
        let k = 2u64;
        let snap = {
            let mut tools = SpiNNTools::new(env_wire(
                ToolsConfig::new(MachineSpec::Spinn5)
                    .with_mapping_threads(threads)
                    .with_checkpoint(every_tick()),
            ))
            .unwrap();
            build_grid(&mut tools, seed);
            tools.run_ticks(k).unwrap();
            tools.suspend().unwrap()
        };
        let mut tools = SpiNNTools::new(env_wire(
            ToolsConfig::new(MachineSpec::Spinn5)
                .with_mapping_threads(threads)
                .with_supervision(supervised())
                .with_checkpoint(every_tick()),
        ))
        .unwrap();
        let ids = build_grid(&mut tools, seed);
        tools.resume_from(&snap).unwrap();
        tools.inject_chaos(ChaosPlan::new().with(k + 1, Fault::ChipDeath(chip)));
        tools.run_ticks(TICKS - k).unwrap();
        let heals = tools.heal_reports();
        assert_eq!(heals.len(), 1, "threads {threads}");
        let restored = heals[0].restored_from_tick.expect("heal must restore from a snapshot");
        assert!(restored >= k, "restore point {restored} predates the resume at {k}");
        assert_eq!(
            recordings(&tools, &ids),
            reference,
            "healed resumed run diverged (threads {threads})"
        );
    }
}

#[test]
fn heal_restores_from_snapshot_not_tick_zero() {
    // The tentpole behaviour: with checkpointing on, a heal resumes from
    // the newest pre-fault snapshot and replays only the tail.
    let seed = base_seed();
    let chip = killable_used_chip(seed);
    let reference = degraded_run(seed, &BootFaults { chips: vec![chip], ..Default::default() });
    let mut tools = SpiNNTools::new(env_wire(
        ToolsConfig::new(MachineSpec::Spinn5)
            .with_supervision(supervised())
            .with_checkpoint(every_tick()),
    ))
    .unwrap();
    let ids = build_grid(&mut tools, seed);
    tools.inject_chaos(ChaosPlan::new().with(3, Fault::ChipDeath(chip)));
    tools.run_ticks(TICKS).unwrap();
    let heals = tools.heal_reports();
    assert_eq!(heals.len(), 1);
    // The fault strikes inside tick window (3, 4); the tick-3 poll was
    // clean, so a tick-3 snapshot exists and is the restore point.
    assert_eq!(heals[0].restored_from_tick, Some(3));
    assert_eq!(recordings(&tools, &ids), reference);
}

#[test]
fn chunk_boundary_chaos_defers_to_next_chunk() {
    // Regression: an event at exactly `abs_done + step` used to be
    // scheduled into the *current* chunk (`<=` instead of `<`), so the
    // tick-2 poll already saw the dead chip and no tick-2 snapshot was
    // ever captured. "After tick 2" must mean after the boundary: the
    // tick-2 poll is clean, the tick-2 snapshot exists, and the fault is
    // observed by the tick-4 poll — one poll later, same strike tick.
    let seed = base_seed();
    let chip = killable_used_chip(seed);
    let reference = degraded_run(seed, &BootFaults { chips: vec![chip], ..Default::default() });
    let mut tools = SpiNNTools::new(env_wire(
        ToolsConfig::new(MachineSpec::Spinn5)
            .with_supervision(SupervisorConfig {
                poll_interval_ticks: 2,
                policy: HealPolicy::Remap,
                max_heals: 4,
            })
            .with_checkpoint(CheckpointConfig { interval_ticks: 2, keep: 2 }),
    ))
    .unwrap();
    let ids = build_grid(&mut tools, seed);
    tools.inject_chaos(ChaosPlan::new().with(2, Fault::ChipDeath(chip)));
    tools.run_ticks(TICKS).unwrap();
    let heals = tools.heal_reports();
    assert_eq!(heals.len(), 1);
    assert_eq!(
        heals[0].restored_from_tick,
        Some(2),
        "boundary poll must predate the boundary fault"
    );
    assert_eq!(recordings(&tools, &ids), reference);
}

/// Build the 3x3 blinker used by the reconcile tests (small enough that
/// removing one cell is a visible mutation).
fn blinker(tools: &mut SpiNNTools) -> Vec<VertexId> {
    let mut ids = Vec::new();
    for r in 0..3u32 {
        for c in 0..3u32 {
            let alive = r == 1;
            ids.push(
                tools
                    .add_machine_vertex(ConwayCellVertex::arc(r, c, alive))
                    .unwrap(),
            );
        }
    }
    let idx = |r: i64, c: i64| -> Option<usize> {
        (r >= 0 && c >= 0 && r < 3 && c < 3).then_some((r * 3 + c) as usize)
    };
    for r in 0..3i64 {
        for c in 0..3i64 {
            for dr in -1..=1 {
                for dc in -1..=1 {
                    if (dr, dc) == (0, 0) {
                        continue;
                    }
                    if let Some(n) = idx(r + dr, c + dc) {
                        tools
                            .add_machine_edge(ids[idx(r, c).unwrap()], ids[n], STATE_PARTITION)
                            .unwrap();
                    }
                }
            }
        }
    }
    ids
}

#[test]
fn reconcile_preserves_recordings_with_checkpointing() {
    // Satellite of the tentpole: a graph mutation between runs used to
    // silently discard everything recorded so far. With checkpointing
    // the pre-mutation recordings survive and the run continues from
    // the snapshot tick.
    let mut tools = SpiNNTools::new(env_wire(
        ToolsConfig::new(MachineSpec::Spinn3).with_checkpoint(every_tick()),
    ))
    .unwrap();
    let ids = blinker(&mut tools);
    tools.run_ticks(2).unwrap();
    let pre = recordings(&tools, &ids);
    assert!(pre.iter().all(|r| r.len() == 2));
    tools.remove_machine_vertex(ids[3]).unwrap(); // (1,0): one wing
    tools.run_ticks(2).unwrap();
    assert_eq!(tools.ticks_done(), 4, "2 restored + 2 new");
    for (i, id) in ids.iter().enumerate() {
        if i == 3 {
            assert!(tools.recording(*id).is_empty(), "removed vertex keeps nothing");
            continue;
        }
        let rec = tools.recording(*id);
        assert_eq!(rec.len(), 4, "vertex {i}: pre-mutation ticks preserved");
        assert_eq!(&rec[..2], &pre[i][..], "vertex {i}: pre-mutation bytes intact");
    }
    let report = tools.provenance();
    assert!(
        !report.anomalies.iter().any(|a| a.contains("discarded")),
        "nothing was discarded: {:?}",
        report.anomalies
    );
}

#[test]
fn reconcile_without_checkpointing_surfaces_the_discard() {
    // The historical behaviour is kept when checkpointing is off, but
    // the discard is no longer silent.
    let mut tools = SpiNNTools::new(env_wire(ToolsConfig::new(MachineSpec::Spinn3))).unwrap();
    let ids = blinker(&mut tools);
    tools.run_ticks(2).unwrap();
    tools.remove_machine_vertex(ids[3]).unwrap();
    tools.run_ticks(2).unwrap();
    assert_eq!(tools.ticks_done(), 2, "restart from tick 0");
    assert_eq!(tools.recording(ids[4]).len(), 2, "only post-mutation ticks remain");
    let report = tools.provenance();
    assert!(
        report.anomalies.iter().any(|a| a.contains("reconcile discarded")),
        "discard must be a provenance anomaly: {:?}",
        report.anomalies
    );
}

#[test]
fn resumed_run_heal_covers_base_ticks() {
    // Satellite regression: run_ticks(a), fault, heal, run_ticks(b) —
    // the heal's restart must cover the base `a` ticks too, with and
    // without a snapshot to restore from.
    let seed = base_seed();
    let chip = killable_used_chip(seed);
    let reference = degraded_run(seed, &BootFaults { chips: vec![chip], ..Default::default() });
    for checkpoint in [None, Some(every_tick())] {
        let mut config =
            ToolsConfig::new(MachineSpec::Spinn5).with_supervision(supervised());
        if let Some(c) = checkpoint {
            config = config.with_checkpoint(c);
        }
        let mut tools = SpiNNTools::new(env_wire(config)).unwrap();
        let ids = build_grid(&mut tools, seed);
        tools.run_ticks(2).unwrap();
        tools.inject_chaos(ChaosPlan::new().with(3, Fault::ChipDeath(chip)));
        tools.run_ticks(TICKS - 2).unwrap();
        assert_eq!(tools.ticks_done(), TICKS);
        let heals = tools.heal_reports();
        assert_eq!(heals.len(), 1);
        assert_eq!(
            heals[0].restored_from_tick,
            checkpoint.map(|_| 3),
            "checkpoint={checkpoint:?}"
        );
        assert_eq!(
            recordings(&tools, &ids),
            reference,
            "checkpoint={checkpoint:?} diverged from the degraded oracle"
        );
    }
}

#[test]
fn file_checkpointer_survives_process_restart() {
    // suspend() in one "process", resume_from() in another: everything
    // needed crosses through the file store.
    let dir = std::env::temp_dir().join(format!(
        "spinntools-ckpt-restart-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);

    let reference = {
        let mut tools = SpiNNTools::new(env_wire(ToolsConfig::new(MachineSpec::Spinn3))).unwrap();
        let ids = blinker(&mut tools);
        tools.run_ticks(4).unwrap();
        recordings(&tools, &ids)
    };

    {
        let mut tools = SpiNNTools::new(env_wire(
            ToolsConfig::new(MachineSpec::Spinn3).with_checkpoint(every_tick()),
        ))
        .unwrap();
        tools.set_checkpointer(Box::new(FileCheckpointer::new(&dir).unwrap()));
        blinker(&mut tools);
        tools.run_ticks(2).unwrap();
        tools.suspend().unwrap();
    } // "process" exits; only the directory survives

    let store = FileCheckpointer::new(&dir).unwrap();
    let newest = *store.snapshot_ticks().last().expect("snapshot on disk");
    assert_eq!(newest, 2);
    let snap = store.get_snapshot(newest).unwrap();
    let mut tools = SpiNNTools::new(env_wire(
        ToolsConfig::new(MachineSpec::Spinn3).with_checkpoint(every_tick()),
    ))
    .unwrap();
    tools.set_checkpointer(Box::new(store));
    let ids = blinker(&mut tools);
    tools.resume_from(&snap).unwrap();
    tools.run_ticks(2).unwrap();
    assert_eq!(recordings(&tools, &ids), reference);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_from_rejects_mismatched_graphs() {
    let snap = {
        let mut tools = SpiNNTools::new(env_wire(
            ToolsConfig::new(MachineSpec::Spinn3).with_checkpoint(every_tick()),
        ))
        .unwrap();
        blinker(&mut tools);
        tools.run_ticks(2).unwrap();
        tools.suspend().unwrap()
    };
    // One vertex short: the revisions cannot match.
    let mut tools = SpiNNTools::new(env_wire(
        ToolsConfig::new(MachineSpec::Spinn3).with_checkpoint(every_tick()),
    ))
    .unwrap();
    tools
        .add_machine_vertex(ConwayCellVertex::arc(0, 0, true))
        .unwrap();
    let err = tools.resume_from(&snap).unwrap_err().to_string();
    assert!(err.contains("do not match the snapshot"), "{err}");
}
