//! Chaos property suite (DESIGN.md §8, experiment E14).
//!
//! The heal property: for seeded random **single-fault** plans — a core
//! RTE, a whole-chip death, or a link death at a random tick — injected
//! into a supervised run, the run completes, and the surviving vertices'
//! recordings are **byte-identical** to a fresh run of the same graph on
//! the *equivalently boot-degraded* machine (the fault expressed as a §2
//! blacklist instead of a runtime event). This holds at mapping
//! worker-pool widths 1, 2 and 8.
//!
//! That single equality is a strong oracle: if the heal left any tree
//! crossing the dead resource, any vertex un-reloaded, or any routing
//! table stale, packets die and the Conway states diverge within a tick
//! or two.
//!
//! `HealPolicy::Abort` is covered separately: the run must stop with a
//! clean error carrying the failed core's IOBUF text.
//!
//! CI runs this suite under a fixed seed matrix via `CHAOS_SEED`.

use std::collections::BTreeSet;

use spinntools::apps::conway::{ConwayCellVertex, STATE_PARTITION};
use spinntools::front::{
    BootFaults, HealPolicy, MachineSpec, SpiNNTools, SupervisorConfig, ToolsConfig,
};
use spinntools::graph::VertexId;
use spinntools::machine::{ChipCoord, CoreLocation, ALL_DIRECTIONS};
use spinntools::simulator::{ChaosPlan, Fault, WireFaults};
use spinntools::util::{prop, SplitMix64};

const ROWS: u32 = 6;
const COLS: u32 = 6;
const TICKS: u64 = 6;

/// Base seed for the property cases; CI sweeps a matrix of these.
fn base_seed() -> u64 {
    std::env::var("CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0A5)
}

/// CI's combined matrix row re-runs this whole suite over an unreliable
/// wire (`WIRE_FAULTS=1`, seeded by `WIRE_SEED`): the reliable transport
/// must make every assertion hold unchanged while frames are being
/// lost, duplicated and reordered underneath the heals.
fn env_wire(config: ToolsConfig) -> ToolsConfig {
    let on = std::env::var("WIRE_FAULTS").map(|v| v != "0" && !v.is_empty()).unwrap_or(false);
    if !on {
        return config;
    }
    let seed = std::env::var("WIRE_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x31E5);
    config.with_wire_faults(WireFaults::from_seed(seed))
}

fn supervised(policy: HealPolicy) -> SupervisorConfig {
    SupervisorConfig { poll_interval_ticks: 1, policy, max_heals: 4 }
}

/// Build the ROWS x COLS Conway grid into `tools`; returns vertex ids.
fn build_grid(tools: &mut SpiNNTools, seed: u64) -> Vec<VertexId> {
    let alive = |r: u32, c: u32| (r.wrapping_mul(31) ^ c.wrapping_mul(17) ^ seed as u32) % 3 == 0;
    let mut ids = Vec::new();
    for r in 0..ROWS {
        for c in 0..COLS {
            ids.push(
                tools
                    .add_machine_vertex(ConwayCellVertex::arc(r, c, alive(r, c)))
                    .unwrap(),
            );
        }
    }
    let idx = |r: i64, c: i64| -> Option<usize> {
        (r >= 0 && c >= 0 && r < ROWS as i64 && c < COLS as i64)
            .then_some((r * COLS as i64 + c) as usize)
    };
    for r in 0..ROWS as i64 {
        for c in 0..COLS as i64 {
            for dr in -1..=1 {
                for dc in -1..=1 {
                    if (dr, dc) == (0, 0) {
                        continue;
                    }
                    if let Some(n) = idx(r + dr, c + dc) {
                        tools
                            .add_machine_edge(ids[idx(r, c).unwrap()], ids[n], STATE_PARTITION)
                            .unwrap();
                    }
                }
            }
        }
    }
    ids
}

/// The deterministic placement of this workload (a scratch pre-run):
/// used to aim faults at resources that actually carry the run.
fn probe_placements(seed: u64) -> Vec<(VertexId, CoreLocation)> {
    let mut probe = SpiNNTools::new(env_wire(ToolsConfig::new(MachineSpec::Spinn5))).unwrap();
    let ids = build_grid(&mut probe, seed);
    probe.run_ticks(1).unwrap();
    let mapping = probe.mapping().unwrap();
    ids.iter().map(|v| (*v, mapping.placement(*v).unwrap())).collect()
}

/// A seeded single fault aimed at a resource the workload uses, plus
/// the equivalent boot-time blacklist.
fn pick_fault(rng: &mut SplitMix64, placements: &[(VertexId, CoreLocation)]) -> (Fault, BootFaults) {
    let machine = MachineSpec::Spinn5.template();
    let used_chips: Vec<ChipCoord> = {
        let set: BTreeSet<ChipCoord> = placements.iter().map(|(_, l)| l.chip()).collect();
        set.into_iter().collect()
    };
    // Chips eligible for whole-chip death: used, but not the Ethernet
    // chip (killing the board's host link is not healable).
    let killable: Vec<ChipCoord> = used_chips
        .iter()
        .copied()
        .filter(|c| !machine.chip(*c).map(|ch| ch.is_ethernet()).unwrap_or(true))
        .collect();
    match rng.below(3) {
        0 => {
            let (_, loc) = placements[rng.below(placements.len())];
            (
                Fault::CoreRte(loc),
                BootFaults { cores: vec![loc], ..Default::default() },
            )
        }
        1 => {
            let chip = killable[rng.below(killable.len())];
            (
                Fault::ChipDeath(chip),
                BootFaults { chips: vec![chip], ..Default::default() },
            )
        }
        _ => {
            // A link between two *used* adjacent chips: Conway cells on
            // both sides exchange state over it every tick, so its death
            // is both observable and harmful until healed.
            let mut pairs = Vec::new();
            for a in &used_chips {
                for d in ALL_DIRECTIONS {
                    if let Some(b) = machine.link_target(*a, d) {
                        if used_chips.contains(&b) {
                            pairs.push((*a, d));
                        }
                    }
                }
            }
            assert!(!pairs.is_empty(), "workload spans adjacent chips");
            let (chip, d) = pairs[rng.below(pairs.len())];
            (
                Fault::LinkDeath(chip, d),
                BootFaults { links: vec![(chip, d)], ..Default::default() },
            )
        }
    }
}

/// Run the workload with the fault injected mid-run and heal it, at the
/// given mapping pool width; return per-vertex recordings.
fn chaos_run(seed: u64, threads: usize, fault: &Fault, at_tick: u64) -> Vec<Vec<u8>> {
    let mut tools = SpiNNTools::new(env_wire(
        ToolsConfig::new(MachineSpec::Spinn5)
            .with_supervision(supervised(HealPolicy::Remap))
            .with_mapping_threads(threads),
    ))
    .unwrap();
    let ids = build_grid(&mut tools, seed);
    tools.inject_chaos(ChaosPlan::new().with(at_tick, fault.clone()));
    tools.run_ticks(TICKS).unwrap_or_else(|e| {
        panic!("supervised run failed to heal {fault} (threads {threads}): {e}")
    });
    // The supervisor must have noticed and healed (every picked fault is
    // observable: a failed core, a dead used chip, or a loaded link).
    let heals = tools.heal_reports();
    assert_eq!(heals.len(), 1, "expected one heal for {fault}, got {}", heals.len());
    assert!(!heals[0].faults.is_empty());
    // Nothing may remain placed on a dead resource.
    let mapping = tools.mapping().unwrap();
    for id in &ids {
        let loc = mapping.placement(*id).unwrap();
        match fault {
            Fault::ChipDeath(c) => assert_ne!(loc.chip(), *c),
            Fault::CoreRte(f) | Fault::CoreStall(f) => assert_ne!(loc, *f),
            // Wire-level faults never aim at placed vertices (and the
            // single-fault plans here never draw them anyway).
            Fault::LinkDeath(_, _) | Fault::LinkBrownout { .. } | Fault::BoardSilent { .. } => {}
        }
    }
    ids.iter().map(|v| tools.recording(*v).to_vec()).collect()
}

/// Run the same workload on the equivalently boot-degraded machine.
fn degraded_run(seed: u64, threads: usize, faults: &BootFaults) -> Vec<Vec<u8>> {
    let mut tools = SpiNNTools::new(env_wire(
        ToolsConfig::new(MachineSpec::Spinn5)
            .with_supervision(supervised(HealPolicy::Remap))
            .with_mapping_threads(threads)
            .with_boot_faults(faults.clone()),
    ))
    .unwrap();
    let ids = build_grid(&mut tools, seed);
    tools.run_ticks(TICKS).unwrap();
    assert!(tools.heal_reports().is_empty(), "boot-degraded run must not need healing");
    ids.iter().map(|v| tools.recording(*v).to_vec()).collect()
}

#[test]
fn heal_property_single_faults_match_boot_degraded_runs() {
    let placements = probe_placements(base_seed());
    prop::check(4, base_seed(), |rng| {
        let seed = base_seed();
        let (fault, boot) = pick_fault(rng, &placements);
        let at_tick = 1 + rng.below(3) as u64;
        let reference = degraded_run(seed, 1, &boot);
        for v in &reference {
            assert_eq!(v.len(), TICKS as usize, "one state byte per tick");
        }
        for threads in [1usize, 2, 8] {
            let healed = chaos_run(seed, threads, &fault, at_tick);
            assert_eq!(
                healed, reference,
                "healed run diverged from boot-degraded run \
                 (fault {fault}, tick {at_tick}, threads {threads})"
            );
            // Pool width must not change the boot-degraded run either.
            if threads > 1 {
                assert_eq!(degraded_run(seed, threads, &boot), reference);
            }
        }
    });
}

#[test]
fn abort_policy_surfaces_clean_error_with_iobuf() {
    let placements = probe_placements(7);
    let victim = placements[placements.len() / 2].1;
    let mut tools = SpiNNTools::new(env_wire(
        ToolsConfig::new(MachineSpec::Spinn5).with_supervision(supervised(HealPolicy::Abort)),
    ))
    .unwrap();
    build_grid(&mut tools, 7);
    tools.inject_chaos(ChaosPlan::new().with(2, Fault::CoreRte(victim)));
    let err = tools.run_ticks(TICKS).unwrap_err().to_string();
    assert!(err.contains("run aborted by supervisor"), "{err}");
    assert!(err.contains(&format!("{victim}")), "{err}");
    assert!(err.contains("[chaos] RTE injected"), "IOBUF text must ride the error: {err}");
    // No heal happened.
    assert!(tools.heal_reports().is_empty());
}

#[test]
fn watchdog_stall_is_detected_and_healed() {
    let placements = probe_placements(11);
    let victim = placements[3].1;
    let mut tools = SpiNNTools::new(env_wire(
        ToolsConfig::new(MachineSpec::Spinn5).with_supervision(supervised(HealPolicy::Remap)),
    ))
    .unwrap();
    let ids = build_grid(&mut tools, 11);
    tools.inject_chaos(ChaosPlan::new().with(2, Fault::CoreStall(victim)));
    tools.run_ticks(TICKS).unwrap();
    let heals = tools.heal_reports();
    assert_eq!(heals.len(), 1);
    assert!(
        heals[0].faults.iter().any(|f| f.contains("watchdog")),
        "{:?}",
        heals[0].faults
    );
    // The stalled core is quarantined: nothing lives there now.
    let mapping = tools.mapping().unwrap();
    for id in &ids {
        assert_ne!(mapping.placement(*id), Some(victim));
    }
    // And the equivalence oracle holds for the stall too.
    let reference = degraded_run(
        11,
        1,
        &BootFaults { cores: vec![victim], ..Default::default() },
    );
    let healed: Vec<Vec<u8>> = ids.iter().map(|v| tools.recording(*v).to_vec()).collect();
    assert_eq!(healed, reference);
}

#[test]
fn max_heals_bounds_a_machine_dying_in_pieces() {
    // Two chip deaths with max_heals = 1: the second fault must abort
    // with the budget-exhausted error rather than looping forever.
    let placements = probe_placements(13);
    let machine = MachineSpec::Spinn5.template();
    let mut used: Vec<ChipCoord> = placements
        .iter()
        .map(|(_, l)| l.chip())
        .filter(|c| !machine.chip(*c).map(|ch| ch.is_ethernet()).unwrap_or(true))
        .collect();
    used.sort();
    used.dedup();
    assert!(used.len() >= 2, "workload must span two killable chips");
    let mut tools = SpiNNTools::new(env_wire(
        ToolsConfig::new(MachineSpec::Spinn5).with_supervision(SupervisorConfig {
            poll_interval_ticks: 1,
            policy: HealPolicy::Remap,
            max_heals: 1,
        }),
    ))
    .unwrap();
    build_grid(&mut tools, 13);
    tools.inject_chaos(
        ChaosPlan::new()
            .with(1, Fault::ChipDeath(used[0]))
            .with(3, Fault::ChipDeath(used[1])),
    );
    let err = tools.run_ticks(TICKS).unwrap_err().to_string();
    assert!(err.contains("failing faster than it can heal"), "{err}");
    assert_eq!(tools.heal_reports().len(), 1, "exactly the budgeted heal ran");
}
