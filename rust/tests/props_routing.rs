//! Property tests (E2/E5 hardening): NER multicast routing validity on
//! random machine graphs over random machines, including machines with
//! faulty links and dead chips.
//!
//! For every outgoing edge partition the routing step must produce a
//! tree that
//!   1. is rooted at the source vertex's chip,
//!   2. contains no cycles (each chip is reached exactly once — the
//!      duplicate-delivery invariant of §6.3.2),
//!   3. only ever hops over links that exist *and* work,
//!   4. covers every source→sink pair: the delivered (chip, core) set is
//!      exactly the placed target set,
//! and the sharded router must produce the identical forest at any
//! worker-pool width.

use std::collections::BTreeSet;

use spinntools::apps::conway::ConwayCellVertex;
use spinntools::graph::MachineGraph;
use spinntools::machine::{ChipCoord, Machine, MachineBuilder, ALL_DIRECTIONS};
use spinntools::mapping::placer::{self, Placements};
use spinntools::mapping::router::{self, RoutingTree};
use spinntools::util::{prop, SplitMix64};

/// A random machine: grid of random size, coin-flip torus wrap, a few
/// dead links and sometimes a dead chip.
fn random_machine(rng: &mut SplitMix64) -> Machine {
    let side = 5 + rng.below(5) as u32;
    let mut b = MachineBuilder::grid(side, side, rng.below(2) == 0);
    for _ in 0..rng.below(7) {
        let c = (rng.below(side as usize) as u32, rng.below(side as usize) as u32);
        let d = ALL_DIRECTIONS[rng.below(6)];
        b = b.dead_link(c, d);
    }
    if rng.below(3) == 0 {
        // Never the boot chip: the radial placer roots its BFS there.
        let c = (1 + rng.below((side - 1) as usize) as u32, rng.below(side as usize) as u32);
        b = b.dead_chip(c);
    }
    b.build()
}

/// A random machine graph with a couple of partitions per vertex.
fn random_graph(rng: &mut SplitMix64) -> MachineGraph {
    let mut g = MachineGraph::new();
    let n = 5 + rng.below(40) as u32;
    let ids: Vec<_> = (0..n)
        .map(|i| g.add_vertex(ConwayCellVertex::arc(i, 0, false)))
        .collect();
    for _ in 0..n * 2 {
        let a = ids[rng.below(ids.len())];
        let b = ids[rng.below(ids.len())];
        if a != b {
            let partition = if rng.below(3) == 0 { "aux" } else { "state" };
            g.add_edge(a, b, partition);
        }
    }
    g
}

/// Walk `tree` from its source, enforcing the structural invariants.
/// Returns the delivered (chip, core) set.
fn validate_tree(machine: &Machine, tree: &RoutingTree) -> Vec<(ChipCoord, u8)> {
    let mut delivered = Vec::new();
    let mut visited = BTreeSet::new();
    let mut stack = vec![tree.source];
    assert!(
        tree.nodes[&tree.source].in_link.is_none(),
        "source chip has an inbound link"
    );
    while let Some(chip) = stack.pop() {
        assert!(
            visited.insert(chip),
            "chip {chip:?} reached twice: cycle or duplicate delivery"
        );
        let node = tree
            .nodes
            .get(&chip)
            .unwrap_or_else(|| panic!("walk reached {chip:?}, not a tree node"));
        for p in &node.local_cores {
            delivered.push((chip, *p));
        }
        for d in &node.out_links {
            let next = machine
                .link_target(chip, *d)
                .unwrap_or_else(|| panic!("tree hop {chip:?} -> {d:?} is not a working link"));
            assert_eq!(
                tree.nodes.get(&next).and_then(|n| n.in_link),
                Some(*d),
                "inbound link of {next:?} disagrees with the walk"
            );
            stack.push(next);
        }
    }
    // No orphan nodes: every tree node was reached from the source.
    let node_chips: BTreeSet<ChipCoord> = tree.nodes.keys().copied().collect();
    assert_eq!(visited, node_chips, "unreachable nodes in the tree");
    delivered.sort();
    delivered
}

fn expected_targets(
    graph: &MachineGraph,
    placements: &Placements,
    partition: &spinntools::graph::machine_graph::OutgoingEdgePartition,
) -> Vec<(ChipCoord, u8)> {
    let mut want: Vec<(ChipCoord, u8)> = graph
        .partition_targets(partition)
        .into_iter()
        .map(|t| {
            let loc = placements.of(t).expect("target placed");
            (loc.chip(), loc.p)
        })
        .collect();
    want.sort();
    want.dedup();
    want
}

#[test]
fn property_ner_trees_are_valid_on_faulty_machines() {
    prop::check(40, 0x0E2_5EED, |rng| {
        let machine = random_machine(rng);
        let graph = random_graph(rng);
        let placements = match placer::place(&machine, &graph) {
            Ok(p) => p,
            Err(_) => return, // machine too small/broken for this graph
        };
        let forest = match router::route(&machine, &graph, &placements) {
            Ok(f) => f,
            Err(_) => return, // faults partitioned the machine: acceptable
        };
        assert_eq!(forest.trees.len(), graph.n_partitions());
        for partition in graph.partitions() {
            let tree = &forest.trees[&(partition.pre, partition.id.clone())];
            let src = placements.of(partition.pre).unwrap();
            assert_eq!(tree.source, src.chip(), "tree rooted off-source");
            let delivered = validate_tree(&machine, tree);
            let want = expected_targets(&graph, &placements, partition);
            assert_eq!(delivered, want, "delivered set mismatch for {:?}", partition.id);
        }
    });
}

#[test]
fn property_sharded_router_matches_serial() {
    prop::check(25, 0x5AA5_0001, |rng| {
        let machine = random_machine(rng);
        let graph = random_graph(rng);
        let Ok(placements) = placer::place(&machine, &graph) else { return };
        let Ok(serial) = router::route(&machine, &graph, &placements) else {
            // If the serial router fails, the sharded one must too (with
            // the deterministic lowest-item error).
            assert!(
                router::route_sharded(&machine, &graph, &placements, 4).is_err(),
                "sharded router succeeded where serial failed"
            );
            return;
        };
        let threads = 2 + rng.below(7);
        let sharded = router::route_sharded(&machine, &graph, &placements, threads).unwrap();
        assert_eq!(
            format!("{serial:?}"),
            format!("{sharded:?}"),
            "forest differs at {threads} threads"
        );
    });
}
