//! Satellite (SpiNNaker2-scale PR): properties of the wafer builder and
//! the two-level hierarchical placer (DESIGN.md §12, experiment E18).
//!
//! - `MachineBuilder::wafer(n)` produces a sound toroid: square, side a
//!   multiple of the 12-chip tile, every chip's nearest-Ethernet entry
//!   pointing at a real Ethernet chip.
//! - `place_hierarchical` is deterministic and thread-invariant
//!   (worker-pool widths 1/2/8), and byte-identical to the flat
//!   first-fit placer both below the dispatch threshold (576 chips,
//!   where `map_graph` still takes the flat path) and above it (a
//!   5184-chip wafer).
//! - A debug-profile smoke run maps a 10k-chip wafer end to end through
//!   `map_graph` (which dispatches to the hierarchical placer at that
//!   scale) and checks the structural invariants of the result.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

use spinntools::graph::{
    DataGenContext, DataRegion, MachineGraph, MachineVertexImpl, ResourceRequirements,
};
use spinntools::machine::{Machine, MachineBuilder};
use spinntools::mapping::{map_graph, placer, MappingConfig, MappingOptions, Placements};

#[derive(Debug)]
struct ScaleVertex {
    idx: u32,
    sdram: u64,
}

impl MachineVertexImpl for ScaleVertex {
    fn label(&self) -> String {
        format!("s{}", self.idx)
    }
    fn resources(&self) -> ResourceRequirements {
        ResourceRequirements::with_sdram(self.sdram)
    }
    fn binary_name(&self) -> String {
        "scale.aplx".into()
    }
    fn generate_data(&self, _ctx: &DataGenContext) -> Vec<DataRegion> {
        vec![]
    }
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// `n` vertices with mildly varied SDRAM appetites (so placement isn't a
/// pure round-robin), optionally ring-connected.
fn scale_graph(n: u32, with_edges: bool) -> MachineGraph {
    let mut g = MachineGraph::new();
    let ids: Vec<_> = (0..n)
        .map(|idx| {
            let sdram = if idx % 7 == 0 { 8 << 20 } else { 1024 };
            g.add_vertex(Arc::new(ScaleVertex { idx, sdram }))
        })
        .collect();
    if with_edges && n > 1 {
        let len = ids.len();
        for (i, v) in ids.iter().enumerate() {
            g.add_edge(*v, ids[(i + 1) % len], "ring");
        }
    }
    g
}

fn placement_fingerprint(p: &Placements) -> String {
    format!("{:?}", p.iter().collect::<Vec<_>>())
}

fn place_flat(machine: &Machine, graph: &MachineGraph) -> Placements {
    placer::place(machine, graph).expect("flat placement")
}

fn place_two_level(machine: &Machine, graph: &MachineGraph, threads: usize) -> Placements {
    placer::place_hierarchical(machine, graph, &BTreeSet::new(), threads)
        .expect("hierarchical placement")
}

#[test]
fn wafer_builder_produces_sound_toroid() {
    for n in [1u32, 100, 1_000, 20_000] {
        let machine = MachineBuilder::wafer(n).build();
        assert_eq!(machine.width, machine.height, "wafer({n}) must be square");
        assert_eq!(machine.width % 12, 0, "wafer({n}) side must tile by 12");
        assert!(
            machine.n_chips() >= n as usize,
            "wafer({n}) holds only {} chips",
            machine.n_chips()
        );
        assert_eq!(
            machine.n_chips(),
            (machine.width * machine.height) as usize,
            "wafer({n}) grid has holes"
        );
        let eths: BTreeSet<_> = machine.ethernet_chips().map(|c| (c.x, c.y)).collect();
        assert!(!eths.is_empty());
        for chip in machine.chips() {
            assert!(
                eths.contains(&chip.nearest_ethernet),
                "chip ({},{}) points at non-Ethernet nearest {:?}",
                chip.x,
                chip.y,
                chip.nearest_ethernet
            );
        }
    }
}

#[test]
fn hierarchical_placer_thread_invariant_above_threshold() {
    // 5184 chips: above HIERARCHICAL_PLACEMENT_THRESHOLD, so this is the
    // shape map_graph actually dispatches to the two-level placer.
    let machine = MachineBuilder::wafer(4_500).build();
    assert!(machine.n_chips() >= placer::HIERARCHICAL_PLACEMENT_THRESHOLD);
    let graph = scale_graph(6_000, false);

    let flat = placement_fingerprint(&place_flat(&machine, &graph));
    let baseline = placement_fingerprint(&place_two_level(&machine, &graph, 1));
    assert_eq!(flat, baseline, "two-level placement diverged from flat");
    // Repeated runs are stable; worker-pool width is invisible.
    for threads in [1usize, 2, 8] {
        let again = placement_fingerprint(&place_two_level(&machine, &graph, threads));
        assert_eq!(baseline, again, "placement differs at {threads} threads");
    }
}

#[test]
fn hierarchical_placer_matches_flat_on_576_chips() {
    // Below the dispatch threshold map_graph keeps the flat placer; the
    // two-level pass must still agree byte-for-byte so the threshold is
    // a pure performance knob, never a behaviour switch.
    let machine = MachineBuilder::boards(12).build();
    assert_eq!(machine.n_chips(), 576);
    assert!(machine.n_chips() < placer::HIERARCHICAL_PLACEMENT_THRESHOLD);
    let graph = scale_graph(2_000, false);

    let flat = placement_fingerprint(&place_flat(&machine, &graph));
    for threads in [1usize, 8] {
        let two_level = placement_fingerprint(&place_two_level(&machine, &graph, threads));
        assert_eq!(flat, two_level, "divergence at 576 chips, {threads} threads");
    }
}

#[test]
fn map_graph_smoke_on_10k_chip_wafer() {
    // Debug-profile end-to-end smoke: a 10k-chip machine through the
    // full pipeline (hierarchical placement, NER routing, keys, tables,
    // capacity check). One ring-connected vertex per chip.
    let machine = MachineBuilder::wafer(10_000).build();
    assert!(machine.n_chips() >= 10_000);
    assert!(machine.n_chips() >= placer::HIERARCHICAL_PLACEMENT_THRESHOLD);
    let n_vertices = machine.n_chips() as u32;
    let graph = scale_graph(n_vertices, true);

    let config = MappingConfig {
        options: MappingOptions::with_threads(0),
        ..Default::default()
    };
    let mapping = map_graph(&machine, &graph, &config).expect("10k-chip map");

    assert_eq!(mapping.placements.len(), n_vertices as usize);
    let mut per_chip: BTreeMap<_, u32> = BTreeMap::new();
    for (_, loc) in mapping.placements.iter() {
        assert_ne!(loc.p, 0, "monitor core used at {loc}");
        *per_chip.entry(loc.chip()).or_default() += 1;
    }
    for (chip, used) in &per_chip {
        let present = machine.chip(*chip).expect("placed on real chip");
        let app_cores = (present.core_mask() & !1).count_ones();
        assert!(*used <= app_cores, "chip {chip:?} oversubscribed");
    }
    // Every vertex owns a key for its outgoing ring partition, and the
    // ring traffic produced real routing tables that all fit the TCAM.
    assert_eq!(mapping.keys.len(), n_vertices as usize);
    assert!(!mapping.tables.is_empty());
    for table in mapping.tables.values() {
        assert!(table.fits(), "oversubscribed table survived the pipeline");
    }
}
