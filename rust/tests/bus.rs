//! Event-bus suite (DESIGN.md §13, experiment E19).
//!
//! The bus property: attaching sinks is **observation-only**. For the
//! same seeded workload, recordings and provenance are byte-identical
//! whether 0 or N sinks watch the run — across mapping worker-pool
//! widths 1, 2 and 8, through supervised chaos heals, and with a stuck
//! sink whose buffer overflows mid-run. Overflow is counted, never
//! reordered; a mid-run subscriber starts at the live cursor.
//!
//! CI's combined matrix row re-runs this suite over an unreliable wire
//! (`WIRE_FAULTS=1`): observation must stay free even while the
//! transport layer is retrying underneath.

use std::cell::RefCell;
use std::rc::Rc;

use spinntools::apps::conway::{ConwayCellVertex, STATE_PARTITION};
use spinntools::apps::networks::build_microcircuit;
use spinntools::front::{
    CallbackSink, HealPolicy, JsonlSink, MachineSpec, RingSink, RunEvent, Sink, SpiNNTools,
    SupervisorConfig, ToolsConfig,
};
use spinntools::graph::VertexId;
use spinntools::machine::CoreLocation;
use spinntools::simulator::{ChaosPlan, Fault, WireFaults};
use spinntools::util::json::Json;

const ROWS: u32 = 6;
const COLS: u32 = 6;
const TICKS: u64 = 8;

fn base_seed() -> u64 {
    std::env::var("WIRE_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x31E5)
}

/// CI's combined matrix row re-runs this suite over an unreliable wire.
fn env_wire(config: ToolsConfig) -> ToolsConfig {
    let on = std::env::var("WIRE_FAULTS").map(|v| v != "0" && !v.is_empty()).unwrap_or(false);
    if !on {
        return config;
    }
    config.with_wire_faults(WireFaults::from_seed(base_seed()))
}

fn artifacts_available() -> bool {
    spinntools::runtime::Runtime::default_dir().join("manifest.json").exists()
}

/// Build the ROWS x COLS Conway grid into `tools`; returns vertex ids.
fn build_grid(tools: &mut SpiNNTools, seed: u64) -> Vec<VertexId> {
    let alive = |r: u32, c: u32| (r.wrapping_mul(31) ^ c.wrapping_mul(17) ^ seed as u32) % 3 == 0;
    let mut ids = Vec::new();
    for r in 0..ROWS {
        for c in 0..COLS {
            ids.push(
                tools
                    .add_machine_vertex(ConwayCellVertex::arc(r, c, alive(r, c)))
                    .unwrap(),
            );
        }
    }
    let idx = |r: i64, c: i64| -> Option<usize> {
        (r >= 0 && c >= 0 && r < ROWS as i64 && c < COLS as i64)
            .then_some((r * COLS as i64 + c) as usize)
    };
    for r in 0..ROWS as i64 {
        for c in 0..COLS as i64 {
            for dr in -1..=1 {
                for dc in -1..=1 {
                    if (dr, dc) == (0, 0) {
                        continue;
                    }
                    if let Some(n) = idx(r + dr, c + dc) {
                        tools
                            .add_machine_edge(ids[idx(r, c).unwrap()], ids[n], STATE_PARTITION)
                            .unwrap();
                    }
                }
            }
        }
    }
    ids
}

/// Always busy: the hub must buffer, then drop-with-count — never stall
/// the run and never hand this sink anything out of order.
struct StuckSink;

impl Sink for StuckSink {
    fn accept(&mut self, _seq: u64, _event: &RunEvent) -> bool {
        false
    }
}

/// The deterministic observable state of a finished run: per-vertex
/// recordings plus the provenance anomalies and wire counters.
fn run_digest(tools: &SpiNNTools, ids: &[VertexId]) -> (Vec<Vec<u8>>, String) {
    let recs: Vec<Vec<u8>> = ids.iter().map(|v| tools.recording(*v).to_vec()).collect();
    let prov = tools.provenance();
    (recs, format!("{:?}|{:?}", prov.anomalies, prov.wire))
}

/// One seeded Conway run; when `watched`, three sinks (a ring, a
/// counting callback and a permanently stuck one) ride along.
fn conway_run(threads: usize, seed: u64, watched: bool) -> (Vec<Vec<u8>>, String, u64) {
    let mut tools = SpiNNTools::new(env_wire(
        ToolsConfig::new(MachineSpec::Spinn5).with_mapping_threads(threads),
    ))
    .unwrap();
    let ring = RingSink::new(1 << 14);
    let count: Rc<RefCell<u64>> = Rc::default();
    if watched {
        tools.bus().attach(Box::new(ring.clone()));
        let c = count.clone();
        tools.bus().attach(Box::new(CallbackSink::new(move |_s, _e| *c.borrow_mut() += 1)));
        tools.bus().attach_buffered(Box::new(StuckSink), 2);
    }
    let ids = build_grid(&mut tools, seed);
    tools.run_ticks(TICKS).unwrap();
    let (recs, digest) = run_digest(&tools, &ids);
    if watched {
        assert!(!ring.is_empty(), "a watched run published nothing");
        assert_eq!(
            *count.borrow(),
            tools.bus().seq(),
            "the healthy callback sink missed events"
        );
    }
    (recs, digest, tools.bus().seq())
}

// ---------------------------------------------------------------------------
// Observation-only: 0 vs N sinks, across mapping pool widths

#[test]
fn conway_runs_byte_identical_with_and_without_sinks_across_threads() {
    let seed = base_seed();
    for threads in [1usize, 2, 8] {
        let (plain, plain_prov, _) = conway_run(threads, seed, false);
        let (watched, watched_prov, events) = conway_run(threads, seed, true);
        assert!(events > 0, "the watched run emitted no events");
        assert_eq!(
            watched, plain,
            "recordings diverged under observation at threads {threads}"
        );
        assert_eq!(
            watched_prov, plain_prov,
            "provenance diverged under observation at threads {threads}"
        );
    }
}

#[test]
fn microcircuit_runs_byte_identical_with_and_without_sinks_across_threads() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let run = |threads: usize, watched: bool| -> (Vec<Vec<u8>>, String) {
        let mut tools = SpiNNTools::new(env_wire(
            ToolsConfig::new(MachineSpec::Spinn5)
                .with_artifacts()
                .with_mapping_threads(threads),
        ))
        .unwrap();
        if watched {
            tools.bus().attach(Box::new(RingSink::new(1 << 14)));
            tools.bus().attach(Box::new(CallbackSink::new(|_s, _e| {})));
            tools.bus().attach_buffered(Box::new(StuckSink), 2);
        }
        let circuit = build_microcircuit(&mut tools, 0.01, 1234, true).unwrap();
        tools.run_ms(20).unwrap();
        let mut recs = Vec::new();
        for (_name, pop) in &circuit.populations {
            for (_slice, data) in tools.app_recordings(*pop) {
                recs.push(data.to_vec());
            }
        }
        let prov = tools.provenance();
        (recs, format!("{:?}|{:?}", prov.anomalies, prov.wire))
    };
    for threads in [1usize, 2, 8] {
        let (plain, plain_prov) = run(threads, false);
        let (watched, watched_prov) = run(threads, true);
        assert!(!plain.is_empty(), "microcircuit recorded nothing");
        assert_eq!(
            watched, plain,
            "microcircuit recordings diverged under observation at threads {threads}"
        );
        assert_eq!(watched_prov, plain_prov);
    }
}

// ---------------------------------------------------------------------------
// Supervised chaos: fault/heal events flow, results don't move

#[test]
fn supervised_heal_streams_chaos_fault_and_heal_events_unchanged() {
    let seed = base_seed() ^ 0xE19;
    // Aim the fault at a core the workload actually uses (scratch
    // pre-run, same trick as the chaos suite).
    let victim: CoreLocation = {
        let mut probe = SpiNNTools::new(env_wire(ToolsConfig::new(MachineSpec::Spinn5))).unwrap();
        let ids = build_grid(&mut probe, seed);
        probe.run_ticks(1).unwrap();
        probe.mapping().unwrap().placement(ids[10]).unwrap()
    };
    let supervised = || {
        env_wire(
            ToolsConfig::new(MachineSpec::Spinn5).with_supervision(SupervisorConfig {
                poll_interval_ticks: 1,
                policy: HealPolicy::Remap,
                max_heals: 4,
            }),
        )
    };
    let run = |watched: bool| -> (Vec<Vec<u8>>, String, Vec<String>) {
        let mut tools = SpiNNTools::new(supervised()).unwrap();
        let ring = RingSink::new(1 << 14);
        if watched {
            tools.bus().attach(Box::new(ring.clone()));
        }
        let ids = build_grid(&mut tools, seed);
        tools.inject_chaos(ChaosPlan::new().with(2, Fault::CoreRte(victim)));
        tools.run_ticks(TICKS).unwrap();
        assert_eq!(tools.heal_reports().len(), 1);
        let (recs, digest) = run_digest(&tools, &ids);
        let kinds = ring.events().iter().map(|(_, e)| e.kind().to_string()).collect();
        (recs, digest, kinds)
    };
    let (plain, plain_prov, _) = run(false);
    let (watched, watched_prov, kinds) = run(true);
    assert_eq!(watched, plain, "heal path diverged under observation");
    assert_eq!(watched_prov, plain_prov);
    for expected in ["run_started", "chaos_injected", "fault", "healed", "run_completed"] {
        assert!(
            kinds.iter().any(|k| k == expected),
            "no {expected:?} event on the bus; saw {kinds:?}"
        );
    }
    // The heal surfaces in provenance too; the bus mirrors anomalies at
    // most once each, so kinds may or may not contain "anomaly" here —
    // what matters above is that watching changed nothing.
}

// ---------------------------------------------------------------------------
// Backpressure and mid-run subscription on a real run

#[test]
fn mid_run_subscriber_sees_only_the_future_in_strict_order() {
    let seed = base_seed();
    let mut tools =
        SpiNNTools::new(env_wire(ToolsConfig::new(MachineSpec::Spinn5))).unwrap();
    let ids = build_grid(&mut tools, seed);
    tools.run_ticks(TICKS / 2).unwrap();
    let already = tools.bus().seq();
    assert!(already > 0, "the first half emitted nothing");
    let seqs: Rc<RefCell<Vec<u64>>> = Rc::default();
    let s = seqs.clone();
    let late = tools
        .bus()
        .attach(Box::new(CallbackSink::new(move |seq, _e| s.borrow_mut().push(seq))));
    // A stuck sink with a tiny buffer rides the same half-run: its
    // overflow must be counted and must not disturb the healthy sink.
    let stuck = tools.bus().attach_buffered(Box::new(StuckSink), 1);
    tools.run_ticks(TICKS / 2).unwrap();
    assert_eq!(tools.bus().attached_at(late), Some(already));
    let seen = seqs.borrow();
    assert!(!seen.is_empty(), "the late subscriber saw nothing");
    assert!(seen[0] == already + 1, "late subscriber must start at the live cursor");
    assert!(
        seen.windows(2).all(|w| w[1] == w[0] + 1),
        "delivery to a healthy sink must be gapless and in order: {seen:?}"
    );
    let emitted_after = tools.bus().seq() - already;
    assert_eq!(tools.bus().delivered(stuck), Some(0));
    assert_eq!(
        tools.bus().dropped(stuck),
        Some(emitted_after.saturating_sub(1)),
        "a stuck sink's overflow must be counted exactly"
    );
    let (recs, _) = run_digest(&tools, &ids);
    assert!(recs.iter().all(|r| r.len() == TICKS as usize), "the run itself was disturbed");
}

#[test]
fn jsonl_sink_writes_one_parseable_object_per_event() {
    let path = std::env::temp_dir().join(format!("spinntools_bus_{}.jsonl", std::process::id()));
    {
        let mut tools =
            SpiNNTools::new(env_wire(ToolsConfig::new(MachineSpec::Spinn5))).unwrap();
        tools.bus().attach(Box::new(JsonlSink::create(&path).unwrap()));
        build_grid(&mut tools, base_seed());
        tools.run_ticks(2).unwrap();
        // Dropping the session drops the sink, which flushes the file.
    }
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let lines: Vec<&str> = text.lines().filter(|l| !l.is_empty()).collect();
    assert!(!lines.is_empty(), "the JSONL sink wrote nothing");
    let mut last_seq = 0;
    for line in lines {
        let obj = Json::parse(line).unwrap_or_else(|e| panic!("bad JSONL line {line:?}: {e}"));
        assert!(obj.get("type").and_then(|t| t.as_str()).is_some());
        let seq = obj.get("seq").and_then(|s| s.as_usize()).unwrap() as u64;
        assert!(seq > last_seq, "JSONL sequence numbers must increase");
        last_seq = seq;
    }
}
