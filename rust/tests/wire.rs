//! Unreliable-wire property suite (DESIGN.md §10, experiment E16).
//!
//! The transport property: under a seeded plan of host-link frame
//! loss, duplication, reordering and jitter, every workload completes
//! with results **byte-identical** to its lossless twin — SCP
//! operations (including non-idempotent alloc/signal) execute exactly
//! once, the bulk data planes re-request their way to complete images,
//! and a board that stops answering altogether is *escalated* (a
//! bounded, distinguishable error, or a supervisor heal) instead of
//! hanging the host.
//!
//! The flip side is pinned too: on a lossless wire the transport layer
//! must be invisible — zero retries, zero timeouts, zero draws.
//!
//! CI runs this suite under a fixed seed matrix via `WIRE_SEED`.

use std::collections::BTreeSet;

use spinntools::apps::conway::{ConwayCellVertex, STATE_PARTITION};
use spinntools::front::{
    BootFaults, DataPlaneOptions, ExtractionMethod, FastPath, HealPolicy, LoadMethod,
    MachineSpec, SpiNNTools, SupervisorConfig, ToolsConfig,
};
use spinntools::graph::VertexId;
use spinntools::machine::{ChipCoord, Machine, MachineBuilder};
use spinntools::simulator::{
    scamp, ChaosPlan, Fault, SimConfig, SimMachine, WireFaults, WireStats,
};
use spinntools::util::{prop, SplitMix64};

const ROWS: u32 = 6;
const COLS: u32 = 6;
const TICKS: u64 = 6;

/// Base seed for the property cases; CI sweeps a matrix of these.
fn base_seed() -> u64 {
    std::env::var("WIRE_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x31E5)
}

/// A simulator booted over a faulty wire. The plan must be in place
/// *at boot* — that is when the wire RNG is seeded.
fn faulty_sim(machine: Machine, faults: WireFaults) -> SimMachine {
    let mut config = SimConfig::default();
    config.wire.faults = faults;
    SimMachine::boot(machine, config)
}

fn pattern(len: usize, seed: u64) -> Vec<u8> {
    let mut rng = SplitMix64::new(seed);
    (0..len).map(|_| (rng.next_u64() & 0xff) as u8).collect()
}

/// Core picker for fast-path system cores (mirrors the E12 suite).
fn picker() -> impl FnMut(ChipCoord) -> Option<u8> {
    let mut used: std::collections::BTreeMap<ChipCoord, u8> = std::collections::BTreeMap::new();
    move |chip| {
        let next = used.entry(chip).or_insert(17);
        let c = *next;
        *next -= 1;
        Some(c)
    }
}

/// Build the ROWS x COLS Conway grid into `tools`; returns vertex ids.
fn build_grid(tools: &mut SpiNNTools, seed: u64) -> Vec<VertexId> {
    let alive = |r: u32, c: u32| (r.wrapping_mul(31) ^ c.wrapping_mul(17) ^ seed as u32) % 3 == 0;
    let mut ids = Vec::new();
    for r in 0..ROWS {
        for c in 0..COLS {
            ids.push(
                tools
                    .add_machine_vertex(ConwayCellVertex::arc(r, c, alive(r, c)))
                    .unwrap(),
            );
        }
    }
    let idx = |r: i64, c: i64| -> Option<usize> {
        (r >= 0 && c >= 0 && r < ROWS as i64 && c < COLS as i64)
            .then_some((r * COLS as i64 + c) as usize)
    };
    for r in 0..ROWS as i64 {
        for c in 0..COLS as i64 {
            for dr in -1..=1 {
                for dc in -1..=1 {
                    if (dr, dc) == (0, 0) {
                        continue;
                    }
                    if let Some(n) = idx(r + dr, c + dc) {
                        tools
                            .add_machine_edge(ids[idx(r, c).unwrap()], ids[n], STATE_PARTITION)
                            .unwrap();
                    }
                }
            }
        }
    }
    ids
}

/// Run the Conway workload under `config`; return (recordings, wire
/// stats).
fn workload_run(config: ToolsConfig, seed: u64) -> (Vec<Vec<u8>>, WireStats) {
    let mut tools = SpiNNTools::new(config).unwrap();
    let ids = build_grid(&mut tools, seed);
    tools.run_ticks(TICKS).unwrap();
    let recs = ids.iter().map(|v| tools.recording(*v).to_vec()).collect();
    (recs, tools.provenance().wire)
}

// ---------------------------------------------------------------------------
// The lossless wire is invisible

#[test]
fn clean_wire_records_zero_transport_work() {
    let (recs, wire) = workload_run(ToolsConfig::new(MachineSpec::Spinn5), base_seed());
    assert!(recs.iter().all(|r| !r.is_empty()), "workload recorded nothing");
    assert_eq!(
        wire,
        WireStats::default(),
        "a lossless wire must report zero retries/timeouts/draws"
    );
}

// ---------------------------------------------------------------------------
// SCP: recovery + exactly-once

#[test]
fn scp_round_trips_exactly_once_under_loss_and_duplication() {
    prop::check(6, base_seed(), |rng| {
        let m = MachineBuilder::spinn5().build();
        let mut sim = faulty_sim(m, WireFaults::from_seed(rng.next_u64()));
        let chip = (3, 4);
        let data = pattern(4096, rng.next_u64());
        // Two allocs over the faulty wire: retransmitted alloc commands
        // must not leak segments, so the second lands exactly one
        // segment after the first.
        let a = scamp::alloc_sdram(&mut sim, chip, data.len() as u32).unwrap();
        let b = scamp::alloc_sdram(&mut sim, chip, data.len() as u32).unwrap();
        assert_eq!(
            b - a,
            data.len() as u32,
            "a retransmitted alloc leaked an SDRAM segment"
        );
        scamp::write_sdram(&mut sim, chip, a, &data).unwrap();
        assert_eq!(scamp::read_sdram(&mut sim, chip, a, data.len()).unwrap(), data);
        scamp::write_sdram_batched(&mut sim, chip, b, &data).unwrap();
        assert_eq!(scamp::read_sdram(&mut sim, chip, b, data.len()).unwrap(), data);
        let stats = sim.wire_stats();
        assert!(
            stats.frames_lost + stats.frames_duplicated + stats.scp_retries > 0,
            "the fault plan never fired: {stats:?}"
        );
        assert_eq!(stats.escalations, 0, "recoverable loss must not escalate");
    });
}

#[test]
fn duplicated_commands_and_replies_are_deduplicated() {
    // A duplication-only plan: every op must still execute exactly once.
    let faults = WireFaults {
        seed: base_seed(),
        dup_h2m_permille: 500,
        dup_m2h_permille: 500,
        ..WireFaults::none()
    };
    let m = MachineBuilder::spinn5().build();
    let mut sim = faulty_sim(m, faults);
    let chip = (2, 5);
    let data = pattern(2048, 0xD0B1);
    let a = scamp::alloc_sdram(&mut sim, chip, data.len() as u32).unwrap();
    let b = scamp::alloc_sdram(&mut sim, chip, data.len() as u32).unwrap();
    assert_eq!(b - a, data.len() as u32);
    scamp::write_sdram(&mut sim, chip, a, &data).unwrap();
    assert_eq!(scamp::read_sdram(&mut sim, chip, a, data.len()).unwrap(), data);
    let stats = sim.wire_stats();
    assert!(
        stats.dup_commands_dropped + stats.dup_replies_dropped > 0,
        "the duplicate checks never fired: {stats:?}"
    );
    assert_eq!(stats.scp_retries, 0, "duplication alone must not cost retries");
}

// ---------------------------------------------------------------------------
// Bulk data plane under the seeded wire

#[test]
fn bulk_planes_round_trip_under_seeded_faults() {
    prop::check(4, base_seed() ^ 0xB01C, |rng| {
        let m = MachineBuilder::spinn5().build();
        let mut sim = faulty_sim(m, WireFaults::from_seed(rng.next_u64()));
        let chip = (5, 5);
        let data = pattern(50_000, rng.next_u64());
        let addr = scamp::alloc_sdram(&mut sim, chip, data.len() as u32).unwrap();
        let fp = FastPath::install(&mut sim, &[chip], picker(), &DataPlaneOptions::default())
            .unwrap();
        scamp::signal_start(&mut sim).unwrap();
        fp.write(&mut sim, chip, addr, &data).unwrap();
        assert_eq!(
            fp.read(&mut sim, chip, addr, data.len()).unwrap(),
            data,
            "bulk image differs after wire-fault recovery"
        );
        let stats = sim.wire_stats();
        assert!(
            stats.frames_lost + stats.frames_duplicated + stats.frames_delayed > 0,
            "the fault plan never touched the data plane: {stats:?}"
        );
        assert_eq!(stats.escalations, 0);
    });
}

#[test]
fn bulk_plane_survives_lost_session_and_read_commands() {
    // Heavy host→machine loss (20%): session-open and read commands are
    // themselves lost regularly, which must surface as re-opened
    // sessions and replayed reads — never as a silently empty write or
    // a hung transfer.
    prop::check(3, base_seed() ^ 0xC3D, |rng| {
        let m = MachineBuilder::spinn5().build();
        let faults = WireFaults {
            seed: rng.next_u64(),
            loss_h2m_permille: 200,
            loss_m2h_permille: 50,
            ..WireFaults::none()
        };
        let mut sim = faulty_sim(m, faults);
        let chip = (6, 3);
        let fp = FastPath::install(&mut sim, &[chip], picker(), &DataPlaneOptions::default())
            .unwrap();
        scamp::signal_start(&mut sim).unwrap();
        for round in 0..2u64 {
            let data = pattern(40_000, rng.next_u64() ^ round);
            let addr = scamp::alloc_sdram(&mut sim, chip, data.len() as u32).unwrap();
            fp.write(&mut sim, chip, addr, &data).unwrap();
            assert_eq!(fp.read(&mut sim, chip, addr, data.len()).unwrap(), data);
        }
        assert!(sim.wire_stats().frames_lost > 0);
    });
}

// ---------------------------------------------------------------------------
// Whole workloads: byte-identical to the lossless twin

#[test]
fn workloads_byte_identical_to_lossless_twin_across_threads() {
    let seed = base_seed();
    for threads in [1usize, 2, 8] {
        let config = || {
            ToolsConfig::new(MachineSpec::Spinn5)
                .with_mapping_threads(threads)
                .with_data_plane_threads(threads)
        };
        let (clean, clean_wire) = workload_run(config(), seed);
        assert_eq!(clean_wire, WireStats::default());
        let (faulty, wire) = workload_run(
            config().with_wire_faults(WireFaults::from_seed(seed ^ threads as u64)),
            seed,
        );
        assert!(
            wire.frames_lost + wire.frames_duplicated + wire.scp_retries > 0,
            "fault plan never fired at threads {threads}: {wire:?}"
        );
        assert_eq!(wire.escalations, 0);
        assert_eq!(
            faulty, clean,
            "recordings diverged from the lossless twin at threads {threads}"
        );
    }
}

#[test]
fn fast_data_plane_workload_byte_identical_under_faults() {
    let seed = base_seed() ^ 0xFA57;
    let config = || {
        ToolsConfig::new(MachineSpec::Spinn5)
            .with_loading(LoadMethod::FastMulticast)
            .with_extraction(ExtractionMethod::FastMulticast)
            .with_data_plane_threads(2)
    };
    let (clean, clean_wire) = workload_run(config(), seed);
    assert_eq!(clean_wire, WireStats::default());
    let (faulty, wire) = workload_run(
        config().with_wire_faults(WireFaults::from_seed(seed)),
        seed,
    );
    assert!(wire.frames_lost + wire.frames_duplicated + wire.frames_delayed > 0);
    assert_eq!(faulty, clean, "fast-plane recordings diverged from the lossless twin");
}

// ---------------------------------------------------------------------------
// Escalation: silence is an error (or a heal), never a hang

#[test]
fn silent_board_escalates_scp_instead_of_hanging() {
    let m = MachineBuilder::spinn5().build();
    let mut sim = faulty_sim(m, WireFaults::none());
    sim.apply_fault(Fault::BoardSilent { board: (0, 0), duration_ns: u64::MAX })
        .unwrap();
    let err = scamp::read_sdram(&mut sim, (2, 2), 0x6000_0000, 64)
        .expect_err("a permanently silent board must fail the exchange")
        .to_string();
    assert!(err.contains("escalated"), "unexpected error shape: {err}");
    let stats = sim.wire_stats();
    assert_eq!(stats.escalations, 1);
    assert_eq!(stats.scp_timeouts, sim.config.wire.scp_retries as u64 + 1);
    assert!(stats.backoff_wait_ns > 0, "retries must pay exponential backoff");
    // Every chip behind the board is now flagged unreachable — what the
    // supervisor turns into a heal.
    assert!(sim.host_unreachable((2, 2)));
    assert!(sim.wire_unreachable_boards().contains(&(0, 0)));
}

#[test]
fn brownout_rides_out_on_backoff() {
    let m = MachineBuilder::spinn5().build();
    let mut sim = faulty_sim(m, WireFaults::none());
    let chip = (1, 1);
    let data = pattern(64, 0xB0);
    let addr = scamp::alloc_sdram(&mut sim, chip, data.len() as u32).unwrap();
    // Total loss for 5 ms: shorter than the retry budget's backoff
    // horizon, so the exchange must wait the episode out and succeed.
    sim.apply_fault(Fault::LinkBrownout {
        board: (0, 0),
        loss_permille: 1000,
        duration_ns: 5_000_000,
    })
    .unwrap();
    scamp::write_sdram(&mut sim, chip, addr, &data).unwrap();
    assert_eq!(scamp::read_sdram(&mut sim, chip, addr, data.len()).unwrap(), data);
    let stats = sim.wire_stats();
    assert!(stats.scp_retries > 0, "the brownout never cost a retry");
    assert_eq!(stats.escalations, 0, "a transient brownout must not escalate");
}

#[test]
fn bulk_plane_rides_out_brownout_on_backoff() {
    // Regression: bulk-plane retry rounds used to advance *no* simulated
    // time when a round came back completely empty, so a total blackout
    // spun all its rounds at one frozen instant inside the episode and
    // escalated — the episode could never expire. Each empty round must
    // pay timeout + capped exponential backoff (mirroring the SCP
    // plane), which lets a brownout shorter than the backoff budget
    // ride out.
    let m = MachineBuilder::spinn5().build();
    let mut sim = faulty_sim(m, WireFaults::none());
    let chip = (1, 1);
    let data = pattern(40_000, 0xB1);
    let addr = scamp::alloc_sdram(&mut sim, chip, data.len() as u32).unwrap();
    let fp = FastPath::install(&mut sim, &[chip], picker(), &DataPlaneOptions::default())
        .unwrap();
    scamp::signal_start(&mut sim).unwrap();
    fp.write(&mut sim, chip, addr, &data).unwrap();
    // Total loss for 5 ms: shorter than the bulk retry budget's backoff
    // horizon, so the read must wait the episode out and succeed.
    sim.apply_fault(Fault::LinkBrownout {
        board: (0, 0),
        loss_permille: 1000,
        duration_ns: 5_000_000,
    })
    .unwrap();
    assert_eq!(
        fp.read(&mut sim, chip, addr, data.len()).unwrap(),
        data,
        "bulk image differs after the brownout"
    );
    let stats = sim.wire_stats();
    assert!(
        stats.bulk_retry_waits > 0,
        "the blackout never cost a bulk retry round: {stats:?}"
    );
    assert_eq!(stats.escalations, 0, "a transient brownout must not escalate");
}

#[test]
fn rediscovery_under_loss_keeps_the_machine_and_drops_silent_boards() {
    let m = MachineBuilder::triads(1, 1).build();
    let n = m.n_chips();
    let boards: Vec<ChipCoord> = m.ethernet_chips().map(|c| (c.x, c.y)).collect();
    assert_eq!(boards.len(), 3);
    let mut sim = faulty_sim(m, WireFaults::lossy(base_seed(), 50));
    // Recoverable loss: the sweep retries invisibly, nothing is dropped.
    let seen = scamp::rediscover_machine(&mut sim, &BTreeSet::new());
    assert_eq!(seen.n_chips(), n, "lossy (but answering) chips were dropped");
    assert!(sim.wire_stats().scp_retries > 0, "the sweep never hit the loss plan");
    // One board goes permanently silent: the sweep must drop exactly
    // that board's chips and keep the rest.
    let dark = boards[1];
    sim.apply_fault(Fault::BoardSilent { board: dark, duration_ns: u64::MAX })
        .unwrap();
    let seen = scamp::rediscover_machine(&mut sim, &BTreeSet::new());
    assert_eq!(seen.n_chips(), n - 48, "a silent board is 48 chips gone");
    assert!(
        seen.chip_coords().all(|c| sim.machine.nearest_ethernet(c) != Some(dark)),
        "chips behind the silent board survived re-discovery"
    );
}

/// All chips of `board` except (optionally) its Ethernet chip.
fn board_chips(machine: &Machine, board: ChipCoord, keep_eth: bool) -> Vec<ChipCoord> {
    machine
        .chip_coords()
        .filter(|c| machine.nearest_ethernet(*c) == Some(board))
        .filter(|c| !(keep_eth && *c == board))
        .collect()
}

#[test]
fn silent_board_escalates_to_heal_byte_identical_to_degraded_twin() {
    let seed = base_seed();
    let spec = MachineSpec::Boards(3);
    let template = spec.template();
    let boards: Vec<ChipCoord> = template.ethernet_chips().map(|c| (c.x, c.y)).collect();
    assert_eq!(boards.len(), 3);
    // Keep the workload off the root board (bar its Ethernet chip, the
    // signal root) so it spans the other boards — one of which can then
    // go dark mid-run.
    let root = boards[0];
    let boot = BootFaults {
        chips: board_chips(&template, root, true),
        ..Default::default()
    };
    let supervision = SupervisorConfig {
        poll_interval_ticks: 1,
        policy: HealPolicy::Remap,
        max_heals: 4,
    };

    // Probe the deterministic placement for a used non-root board.
    let dark = {
        let mut probe = SpiNNTools::new(
            ToolsConfig::new(spec).with_boot_faults(boot.clone()),
        )
        .unwrap();
        let ids = build_grid(&mut probe, seed);
        probe.run_ticks(1).unwrap();
        let mapping = probe.mapping().unwrap();
        let used: BTreeSet<ChipCoord> = ids
            .iter()
            .filter_map(|v| mapping.placement(*v))
            .filter_map(|loc| template.nearest_ethernet(loc.chip()))
            .collect();
        *used
            .iter()
            .find(|b| **b != root)
            .expect("workload must span a non-root board")
    };

    // The run under test: the used board goes permanently silent at
    // tick 2; the supervisor must power it off and heal around it.
    let mut tools = SpiNNTools::new(
        ToolsConfig::new(spec)
            .with_boot_faults(boot.clone())
            .with_supervision(supervision),
    )
    .unwrap();
    let ids = build_grid(&mut tools, seed);
    tools.inject_chaos(ChaosPlan::new().with(
        2,
        Fault::BoardSilent { board: dark, duration_ns: u64::MAX },
    ));
    tools
        .run_ticks(TICKS)
        .unwrap_or_else(|e| panic!("a silent board must heal, not fail: {e}"));
    let heals = tools.heal_reports();
    assert_eq!(heals.len(), 1, "expected exactly one heal");
    assert!(
        heals[0].faults.iter().any(|f| f.contains("unreachable")),
        "heal did not classify the silent board: {:?}",
        heals[0].faults
    );
    let mapping = tools.mapping().unwrap();
    for id in &ids {
        let chip = mapping.placement(*id).unwrap().chip();
        assert_ne!(
            template.nearest_ethernet(chip),
            Some(dark),
            "a vertex is still placed behind the silent board"
        );
    }
    let healed: Vec<Vec<u8>> = ids.iter().map(|v| tools.recording(*v).to_vec()).collect();

    // The oracle: a fresh run on the equivalently boot-degraded machine
    // (the whole dark board blacklisted) must record identical bytes.
    let mut dead = boot;
    dead.chips.extend(board_chips(&template, dark, false));
    let mut twin = SpiNNTools::new(
        ToolsConfig::new(spec)
            .with_boot_faults(dead)
            .with_supervision(supervision),
    )
    .unwrap();
    let twin_ids = build_grid(&mut twin, seed);
    twin.run_ticks(TICKS).unwrap();
    assert!(twin.heal_reports().is_empty(), "the degraded twin must not heal");
    let reference: Vec<Vec<u8>> =
        twin_ids.iter().map(|v| twin.recording(*v).to_vec()).collect();
    assert_eq!(healed, reference, "healed run diverged from the degraded twin");
}

#[test]
fn unsupervised_silent_board_is_a_bounded_error() {
    let mut tools = SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn5)).unwrap();
    build_grid(&mut tools, base_seed());
    tools.inject_chaos(ChaosPlan::new().with(
        1,
        Fault::BoardSilent { board: (0, 0), duration_ns: u64::MAX },
    ));
    let err = tools
        .run_ticks(TICKS)
        .expect_err("an unsupervised run against a silent board must error, not hang")
        .to_string();
    assert!(err.contains("silent") || err.contains("unreachable"), "error shape: {err}");
}
