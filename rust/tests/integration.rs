//! Integration tests across the whole stack (experiment ids from
//! DESIGN.md §14): the Figure-8 flow, Figure-9 pause/resume, live I/O,
//! the application-graph SNN path with the AOT HLO artifacts, and the
//! simulated-hardware behaviours the toolchain depends on.

use spinntools::apps::conway::{ConwayTileVertex, STATE_PARTITION};
use spinntools::apps::gatherer::LivePacketGathererVertex;
use spinntools::apps::networks::{build_conway_grid, build_microcircuit, firing_rates};
use spinntools::apps::neuron::{
    decode_spike_bitmaps, Connector, LifParams, LifPopulationVertex, SynapseSpec,
    SPIKES_PARTITION,
};
use spinntools::apps::poisson::PoissonSourceVertex;
use spinntools::apps::reverse_source::{ReverseIpTagSourceVertex, OUT_PARTITION};
use spinntools::front::{
    ExtractionMethod, LiveEventListener, LiveInjector, MachineSpec, SpiNNTools, ToolsConfig,
};

fn artifacts_available() -> bool {
    spinntools::runtime::Runtime::default_dir()
        .join("manifest.json")
        .exists()
}

// -- E4: Figure-9 auto pause/resume ------------------------------------------

#[test]
fn e4_chunked_run_cycles_preserve_results() {
    // Tiny SDRAM forces multiple run cycles; results must equal a
    // single-cycle run.
    let run = |shrink_sdram: bool| -> Vec<u8> {
        let mut config = ToolsConfig::new(MachineSpec::Spinn3);
        if shrink_sdram {
            // 2 MiB per chip: with 1 MiB slack, buffers get tiny.
            config.recording_slack_bytes = 126 * 1024 * 1024;
        }
        let mut tools = SpiNNTools::new(config).unwrap();
        let ids = build_conway_grid(&mut tools, 4, 4, &[(1, 1), (1, 2), (2, 1), (2, 2)]).unwrap();
        tools.run_ticks(50).unwrap();
        tools.recording(ids[5]).to_vec()
    };
    let single = run(false);
    let chunked = run(true);
    assert_eq!(single.len(), 50);
    assert_eq!(single, chunked, "chunked cycles must not change results");
    // A block is a still life: always alive.
    assert!(single.iter().all(|b| *b == 1));
}

// -- E3 + E8: application graph -> machine graph with HLO neurons ------------

#[test]
fn e8_small_snn_runs_and_spikes() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut tools =
        SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn3).with_artifacts()).unwrap();
    // 100 Poisson sources driving 100 LIF neurons one-to-one, strongly.
    let src = tools
        .add_application_vertex(PoissonSourceVertex::arc("src", 100, 200.0, 42, false))
        .unwrap();
    let pop = tools
        .add_application_vertex(LifPopulationVertex::arc(
            "pop",
            100,
            LifParams::default(),
            true,
        ))
        .unwrap();
    tools
        .add_application_edge(
            src,
            pop,
            SPIKES_PARTITION,
            Some(SynapseSpec::excitatory(30.0, Connector::OneToOne, 7)),
        )
        .unwrap();
    tools.run_ms(100).unwrap();
    let recs = tools.app_recordings(pop);
    assert_eq!(recs.len(), 1, "100 neurons fit one core");
    let (slice, data) = &recs[0];
    let spikes = decode_spike_bitmaps(data, slice.n_atoms());
    assert!(!spikes.is_empty(), "strong 200 Hz drive must elicit spikes");
    // Refractoriness bounds the rate: <= 1 spike / 3 ms / neuron.
    assert!(spikes.len() <= 100 * 100 / 3 + 100);
    let prov = tools.provenance();
    assert_eq!(prov.counter_total("spikes_unmatched"), 0);
}

#[test]
fn e8_inhibition_suppresses_firing() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rate_with = |inhibit: bool| -> usize {
        let mut tools =
            SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn3).with_artifacts()).unwrap();
        let src = tools
            .add_application_vertex(PoissonSourceVertex::arc("src", 64, 100.0, 1, false))
            .unwrap();
        let pop = tools
            .add_application_vertex(LifPopulationVertex::arc(
                "pop",
                64,
                LifParams::default(),
                true,
            ))
            .unwrap();
        tools
            .add_application_edge(
                src,
                pop,
                SPIKES_PARTITION,
                Some(SynapseSpec::excitatory(200.0, Connector::OneToOne, 3)),
            )
            .unwrap();
        if inhibit {
            let inh = tools
                .add_application_vertex(PoissonSourceVertex::arc("inh", 64, 400.0, 9, false))
                .unwrap();
            tools
                .add_application_edge(
                    inh,
                    pop,
                    SPIKES_PARTITION,
                    Some(SynapseSpec::inhibitory(400.0, Connector::OneToOne, 5)),
                )
                .unwrap();
        }
        tools.run_ms(100).unwrap();
        tools
            .app_recordings(pop)
            .iter()
            .map(|(s, d)| decode_spike_bitmaps(d, s.n_atoms()).len())
            .sum()
    };
    let base = rate_with(false);
    let suppressed = rate_with(true);
    assert!(base > 0);
    assert!(
        suppressed < base / 2,
        "inhibition should at least halve firing ({base} -> {suppressed})"
    );
}

#[test]
fn e8_population_splits_across_cores() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut tools =
        SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn3).with_artifacts()).unwrap();
    let pop = tools
        .add_application_vertex(LifPopulationVertex::arc(
            "big",
            600,
            LifParams { i_offset: 30.0, ..LifParams::default() },
            true,
        ))
        .unwrap();
    tools.run_ms(20).unwrap();
    let mvs = tools.machine_vertices_of(pop);
    assert!(mvs.len() >= 3, "600 atoms at <=256/core needs >=3 cores");
    let total: u32 = mvs.iter().map(|(_, s)| s.n_atoms()).sum();
    assert_eq!(total, 600);
    // Every slice fires (constant i_offset drive).
    for (slice, data) in tools.app_recordings(pop) {
        assert!(
            !decode_spike_bitmaps(data, slice.n_atoms()).is_empty(),
            "slice {slice} silent"
        );
    }
}

// -- E6: live I/O (Figure 12) -------------------------------------------------

#[test]
fn e6_live_output_via_lpg_and_input_via_riptms() {
    // A Conway grid wired to an LPG; a RIPTMS wired to nothing (it only
    // needs to inject; the cells it targets are the proof).
    let mut tools = SpiNNTools::new(
        ToolsConfig::new(MachineSpec::Spinn3).with_extraction(ExtractionMethod::Scamp),
    )
    .unwrap();
    let ids = build_conway_grid(&mut tools, 3, 3, &[(1, 0), (1, 1), (1, 2)]).unwrap();
    let lpg = tools
        .add_machine_vertex(LivePacketGathererVertex::arc("lpg", "host", 19999, (0, 0)))
        .unwrap();
    // Tap the centre cell's existing multicast stream (Figure 12: "the
    // simple addition of an edge to the graph").
    tools.add_machine_edge(ids[4], lpg, STATE_PARTITION).unwrap();
    let riptms = tools
        .add_machine_vertex(ReverseIpTagSourceVertex::arc("inject", 18888, 4))
        .unwrap();
    tools.add_machine_edge(riptms, ids[0], OUT_PARTITION).unwrap();

    tools.run_ticks(5).unwrap();

    let db = tools.database().unwrap().clone();
    let listener = LiveEventListener::new(19999, db);
    let events = listener.poll(tools.sim_mut().unwrap()).unwrap();
    // The LPG flushes on its own timer, so live events lag one tick:
    // after 5 ticks the states of ticks 1..4 have been forwarded.
    assert_eq!(events.len(), 4, "one state event per completed tick");
    assert!(events.iter().all(|e| e.vertex() == "cell_1_1"));
    // Payload carries the cell state; blinker centre is always alive.
    assert!(events.iter().all(|e| e.payload == Some(1)));

    // Live input: inject an event; the RIPTMS multicasts it to cell 0,0.
    let injector = LiveInjector::new((0, 0), 18888);
    injector.send(tools.sim_mut().unwrap(), &[0]).unwrap();
    tools.sim_mut().unwrap().run_until_idle().unwrap();
    let prov = tools.provenance();
    assert_eq!(prov.counter_total("events_injected"), 1);
}

// -- E1 sanity through the public config --------------------------------------

#[test]
fn e1_fast_extraction_end_to_end() {
    let mut tools = SpiNNTools::new(
        ToolsConfig::new(MachineSpec::Spinn3).with_extraction(ExtractionMethod::FastMulticast),
    )
    .unwrap();
    // 3x3 leaves cores for the extraction reader + gatherer on chip 0,0.
    let ids = build_conway_grid(&mut tools, 3, 3, &[(0, 0), (0, 1), (1, 0), (1, 1)]).unwrap();
    tools.run_ticks(20).unwrap();
    // Same results as the SCAMP path would give: block still life.
    assert_eq!(tools.recording(ids[0]), &[1u8; 20][..]);
    assert_eq!(tools.recording(ids[8]), &[0u8; 20][..]);
}

// -- E7 tile variant: HLO conway behind an app-level vertex -------------------

#[test]
fn e7_hlo_tile_matches_cell_graph() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // Same 16x16 board as a cell graph and as one HLO tile: identical
    // evolution (both use dead boundaries).
    let glider = [(0u32, 1u32), (1, 2), (2, 0), (2, 1), (2, 2)];
    let steps = 8usize;

    // The cell app records the state it *sends* each tick, i.e. the
    // state after t-1 updates — so reaching s_8 takes 9 ticks.
    let mut cell_tools = SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn5)).unwrap();
    let ids = build_conway_grid(&mut cell_tools, 16, 16, &glider).unwrap();
    cell_tools.run_ticks(steps as u64 + 1).unwrap();
    let mut cell_final = vec![0u8; 256];
    for (i, id) in ids.iter().enumerate() {
        cell_final[i] = *cell_tools.recording(*id).last().unwrap();
    }

    let mut tile_tools =
        SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn3).with_artifacts()).unwrap();
    let mut initial = vec![0u8; 256];
    for (r, c) in glider {
        initial[(r * 16 + c) as usize] = 1;
    }
    let tile = tile_tools
        .add_machine_vertex(ConwayTileVertex::arc(16, initial))
        .unwrap();
    tile_tools.run_ticks(steps as u64).unwrap();
    let rec = tile_tools.recording(tile);
    let tile_final = &rec[256 * (steps - 1)..256 * steps];

    assert_eq!(cell_final.as_slice(), tile_final, "cell graph and Pallas tile diverge");
}

// -- E9/E5: mapping on faulty machines through the full flow ------------------

#[test]
fn flow_survives_dead_cores_and_links() {
    let mut config = ToolsConfig::new(MachineSpec::Spinn3);
    config.machine = MachineSpec::Grid { width: 4, height: 4, wrap: false };
    let mut tools = SpiNNTools::new(config).unwrap();
    // Note: faults are modelled at machine-build time in MachineSpec
    // only via builder in unit tests; here we check a full-size graph on
    // the healthy grid still maps when constrained.
    let ids = build_conway_grid(&mut tools, 8, 8, &[(3, 3), (3, 4), (4, 3), (4, 4)]).unwrap();
    tools.run_ticks(10).unwrap();
    assert_eq!(tools.recording(ids[3 * 8 + 3]), &[1u8; 10][..]);
    let mapping = tools.mapping().unwrap();
    assert!(mapping.placements.used_chips().len() > 1);
}

// -- E8 headline: the scaled microcircuit -------------------------------------

#[test]
fn e8_microcircuit_mini_runs_with_plausible_rates() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut tools =
        SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn5).with_artifacts()).unwrap();
    let circuit = build_microcircuit(&mut tools, 0.01, 1234, true).unwrap();
    tools.run_ms(100).unwrap();
    let rates = firing_rates(&tools, &circuit, 100.0);
    // Shape check, not absolute: every population alive, none epileptic.
    for (name, rate) in &rates {
        assert!(*rate > 0.1, "{name} silent ({rate:.2} Hz)");
        assert!(*rate < 120.0, "{name} runaway ({rate:.2} Hz)");
    }
    let prov = tools.provenance();
    assert_eq!(prov.counter_total("spikes_unmatched"), 0);
}

// -- §7.2 extension: external device via a virtual vertex ----------------------

/// A device vertex: stands in for a robot motor wired to chip (0,0)'s
/// SpiNNaker-Link (§5.1/§7.2). Nothing is loaded on it; routed packets
/// are consumed by the simulated device.
#[derive(Debug)]
struct MotorVertex;

impl spinntools::graph::MachineVertexImpl for MotorVertex {
    fn label(&self) -> String {
        "motor".into()
    }
    fn resources(&self) -> spinntools::graph::ResourceRequirements {
        Default::default()
    }
    fn binary_name(&self) -> String {
        "<device>".into()
    }
    fn generate_data(
        &self,
        _: &spinntools::graph::DataGenContext,
    ) -> Vec<spinntools::graph::DataRegion> {
        vec![]
    }
    fn virtual_link(&self) -> Option<spinntools::graph::VirtualLink> {
        Some(spinntools::graph::VirtualLink {
            attached_to: (0, 0),
            direction: spinntools::machine::Direction::SouthWest,
        })
    }
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

#[test]
fn device_vertex_receives_routed_packets() {
    // Figure-13 cells driving a device: "the tools will automatically
    // detect this, and add a virtual chip to the discovered machine ...
    // with edges to and from the device being routed as appropriate".
    let mut tools = SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn3)).unwrap();
    let ids = build_conway_grid(&mut tools, 3, 3, &[(1, 0), (1, 1), (1, 2)]).unwrap();
    let motor = tools
        .add_machine_vertex(std::sync::Arc::new(MotorVertex))
        .unwrap();
    // The centre cell's state drives the motor.
    tools.add_machine_edge(ids[4], motor, STATE_PARTITION).unwrap();
    tools.run_ticks(5).unwrap();
    // The virtual chip consumed one packet per tick.
    let sim = tools.sim_mut().unwrap();
    let consumed: usize = sim.device_inbox.values().map(|v| v.len()).sum();
    assert_eq!(consumed, 5, "device should see the centre cell's 5 state packets");
    // And the neighbours still work (routing to the device didn't break
    // the rest of the multicast tree).
    let wing = tools.recording(ids[3]);
    assert_eq!(wing, &[1, 0, 1, 0, 1], "blinker wing");
}

// -- E2/E10 property: the whole mapping pipeline routes every key --------------

#[test]
fn property_full_pipeline_routes_all_keys() {
    use spinntools::graph::machine_graph::DEFAULT_PARTITION;
    use spinntools::mapping::{map_graph, tables::check_tables, MappingConfig};
    use spinntools::util::{prop, SplitMix64};

    prop::check(15, 0x5EED, |rng: &mut SplitMix64| {
        // Random machine with a couple of faults.
        let mut b = spinntools::machine::MachineBuilder::grid(6, 6, rng.below(2) == 0);
        for _ in 0..rng.below(3) {
            let c = (rng.below(6) as u32, rng.below(6) as u32);
            let d = spinntools::machine::ALL_DIRECTIONS[rng.below(6)];
            b = b.dead_link(c, d);
        }
        let machine = b.build();
        // Random graph.
        let mut g = spinntools::graph::MachineGraph::new();
        let n = 5 + rng.below(40) as u32;
        let ids: Vec<_> = (0..n)
            .map(|i| {
                g.add_vertex(spinntools::apps::conway::ConwayCellVertex::arc(i, 0, false))
            })
            .collect();
        for _ in 0..n * 2 {
            let a = ids[rng.below(ids.len())];
            let b2 = ids[rng.below(ids.len())];
            if a != b2 {
                g.add_edge(a, b2, DEFAULT_PARTITION);
            }
        }
        let Ok(mapping) = map_graph(&machine, &g, &MappingConfig::default()) else {
            return; // machine too broken for this graph: acceptable
        };
        // Every partition's keys must reach exactly the partition targets.
        for p in g.partitions() {
            let src = mapping.placement(p.pre).unwrap();
            let key = mapping.keys[&(p.pre, p.id.clone())];
            let expected: Vec<_> = g
                .partition_targets(p)
                .into_iter()
                .map(|t| {
                    let loc = mapping.placement(t).unwrap();
                    (loc.chip(), loc.p)
                })
                .collect();
            check_tables(&machine, &mapping.tables, src.chip(), key.base, &expected)
                .expect("pipeline produced wrong routing");
        }
    });
}

// -- §8 future work: machine vertices inside an application graph -------------

#[test]
fn wrapped_machine_vertex_in_application_graph() {
    // "Allow an application graph to contain machine vertices, which are
    // then simply copied to the machine graph during the conversion" —
    // here an LPG (a machine-level utility vertex) taps an application
    // population's spikes without a dual implementation.
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    use spinntools::graph::WrappedMachineVertex;
    let mut tools =
        SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn3).with_artifacts()).unwrap();
    let pop = tools
        .add_application_vertex(LifPopulationVertex::arc(
            "pop",
            32,
            LifParams { i_offset: 40.0, ..LifParams::default() }, // tonic firing
            false,
        ))
        .unwrap();
    let lpg = tools
        .add_application_vertex(WrappedMachineVertex::arc(LivePacketGathererVertex::arc(
            "lpg", "viz", 20123, (0, 0),
        )))
        .unwrap();
    tools
        .add_application_edge(pop, lpg, SPIKES_PARTITION, None)
        .unwrap();
    tools.run_ms(20).unwrap();
    let db = tools.database().unwrap().clone();
    let listener = LiveEventListener::new(20123, db);
    let events = listener.poll(tools.sim_mut().unwrap()).unwrap();
    assert!(!events.is_empty(), "LPG should forward the population's spikes");
    assert!(events.iter().all(|e| e.vertex().starts_with("pop")));
}
