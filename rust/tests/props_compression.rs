//! Property tests (experiment E10 hardening): ordered-covering routing
//! table compression is semantics-preserving.
//!
//! Two compressors, two contracts:
//!
//! - [`compress_exact`] preserves the semantics of **every** 32-bit key:
//!   a key that matched before compression routes to the same link/core
//!   set after, and a previously-dead key stays dead (buddy merges are
//!   exact unions).
//! - [`compress`] (the production ordered-covering pass) preserves every
//!   **matched** key; never-matched keys may be captured by a wider
//!   cover — the order-exploiting trade of Mundy et al. 2016, safe on
//!   SpiNNaker because unallocated keys are never sent. The properties
//!   here pin down exactly that boundary: a key whose route *changes*
//!   must have been dead before.

use spinntools::machine::router::{Route, RoutingEntry, RoutingTable};
use spinntools::mapping::compress::{compress, compress_exact};
use spinntools::util::{prop, SplitMix64};

/// Allocator-shaped random table: aligned power-of-two blocks in a
/// handful of route groups, with cross-route overlaps dropped (the key
/// allocator never produces them, and overlap makes "the matched route"
/// order-dependent).
fn random_table(rng: &mut SplitMix64) -> RoutingTable {
    let n_groups = 1 + rng.below(4);
    let mut entries = Vec::new();
    for g in 0..n_groups {
        let route = Route(1 << g);
        for _ in 0..1 + rng.below(12) {
            let block_bits = rng.below(6) as u32;
            let block = 1u32 << block_bits;
            let base = (rng.below(64) as u32) * block;
            entries.push(RoutingEntry::new(base, !(block - 1), route));
        }
    }
    let mut clean: Vec<RoutingEntry> = Vec::new();
    'outer: for cand in entries {
        for kept in &clean {
            if kept.intersects(&cand) && kept.route != cand.route {
                continue 'outer;
            }
        }
        clean.push(cand);
    }
    RoutingTable::from_entries(clean)
}

/// Every key any original entry matches (blocks here are at most 32
/// keys, so exhaustive enumeration is cheap).
fn matched_keys(table: &RoutingTable) -> Vec<u32> {
    let mut keys = Vec::new();
    for e in table.entries() {
        let lo = e.key & e.mask;
        let hi = lo | !e.mask;
        keys.extend(lo..=hi);
    }
    keys.sort_unstable();
    keys.dedup();
    keys
}

#[test]
fn property_exact_compression_preserves_all_keys() {
    prop::check(60, 0xE10_AC7, |rng| {
        let t = random_table(rng);
        let c = compress_exact(&t);
        assert!(c.len() <= t.len(), "exact compression grew the table");

        // 1. Every matched key keeps its exact route word.
        for key in matched_keys(&t) {
            assert_eq!(
                t.lookup(key),
                c.lookup(key),
                "matched key {key:#x} changed route"
            );
        }

        // 2. No previously-dead key becomes live: the populated region
        // (all blocks live below 64 * 32 = 2048) is swept densely, and
        // the rest of the 32-bit space is sampled at random.
        for key in 0..4096u32 {
            assert_eq!(
                t.lookup(key),
                c.lookup(key),
                "key {key:#x} changed liveness/route"
            );
        }
        for _ in 0..2000 {
            let key = rng.next_u64() as u32;
            assert_eq!(
                t.lookup(key),
                c.lookup(key),
                "sampled key {key:#x} changed liveness/route"
            );
        }
    });
}

#[test]
fn property_aggressive_compression_preserves_matched_keys() {
    prop::check(60, 0xE10_FACE, |rng| {
        let t = random_table(rng);
        let c = compress(&t);
        assert!(c.len() <= t.len(), "compression grew the table");

        // Every matched key keeps its route...
        for key in matched_keys(&t) {
            assert_eq!(
                t.lookup(key),
                c.lookup(key),
                "matched key {key:#x} changed route"
            );
        }

        // ...and any key whose lookup changed must have been dead before
        // (only never-sent keys may be captured by a wider cover), and
        // it must land on a route that already existed in the table.
        let live_routes: Vec<Route> =
            t.entries().iter().map(|e| e.route).collect();
        for key in 0..4096u32 {
            let before = t.lookup(key);
            let after = c.lookup(key);
            if before != after {
                assert_eq!(before, None, "live key {key:#x} was rerouted");
                let got = after.expect("changed key must now match something");
                assert!(
                    live_routes.contains(&got),
                    "captured key {key:#x} got a novel route {got:?}"
                );
            }
        }
    });
}

#[test]
fn property_compression_is_idempotent_enough_to_fit() {
    // Compressing an already-compressed table never grows it and keeps
    // matched-key semantics (a regression guard for the sort order).
    prop::check(20, 0x1D_E4, |rng| {
        let t = random_table(rng);
        let once = compress(&t);
        let twice = compress(&once);
        assert!(twice.len() <= once.len());
        for key in matched_keys(&t) {
            assert_eq!(t.lookup(key), twice.lookup(key), "key {key:#x}");
        }
    });
}
