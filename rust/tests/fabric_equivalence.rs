//! E11 satellite: the fast fabric (flat arena + route cache + calendar
//! queue) is byte-identical to the legacy fabric on every observable:
//! routing decisions, event dispatch order, router/sim statistics and
//! end-to-end workload results (Conway recordings, microcircuit-storm
//! provenance).

use spinntools::apps::networks::build_conway_grid;
use spinntools::front::fabric_probe::{run_fabric_probe, ProbeWorkload};
use spinntools::front::{MachineSpec, SpiNNTools, ToolsConfig};
use spinntools::machine::router::{
    PacketSource, Route, RouteCache, RoutingEntry, RoutingTable,
};
use spinntools::machine::Direction;
use spinntools::simulator::queue::{CalendarQueue, HeapQueue};
use spinntools::simulator::FabricMode;
use spinntools::util::SplitMix64;

// ---------------------------------------------------------------------------
// cached vs uncached routing decisions

fn random_table(rng: &mut SplitMix64, entries: usize) -> RoutingTable {
    let mut t = RoutingTable::new();
    for _ in 0..entries {
        // Masks with a random prefix width; keys under the mask.
        let width = rng.below(33) as u32;
        let mask = if width == 0 { 0 } else { u32::MAX << (32 - width) };
        let key = (rng.next_u64() as u32) & mask;
        let mut route = Route::EMPTY;
        if rng.next_f64() < 0.7 {
            route = route.with_link(Direction::from_id(rng.below(6) as u8).unwrap());
        }
        if rng.next_f64() < 0.5 {
            route = route.with_processor(rng.below(18) as u8);
        }
        t.push(RoutingEntry::new(key, mask, route));
    }
    t
}

fn random_source(rng: &mut SplitMix64) -> PacketSource {
    if rng.next_f64() < 0.5 {
        PacketSource::Local(rng.below(18) as u8)
    } else {
        PacketSource::Link(Direction::from_id(rng.below(6) as u8).unwrap())
    }
}

#[test]
fn cached_routing_matches_uncached_on_random_tables() {
    let mut rng = SplitMix64::new(0xCAC4E);
    for round in 0..50 {
        let n_entries = 1 + rng.below(64);
        let table = random_table(&mut rng, n_entries);
        let mut cache = RouteCache::new();
        // A small key pool guarantees plenty of cache hits.
        let pool: Vec<u32> = (0..16).map(|_| rng.next_u64() as u32).collect();
        let mut hits = 0u32;
        for _ in 0..200 {
            let key = pool[rng.below(pool.len())];
            let from = random_source(&mut rng);
            let (cached, hit) = cache.route(&table, key, from);
            assert_eq!(
                cached,
                table.route_packet(key, from),
                "round {round}: cache diverged on key {key:#x}"
            );
            hits += hit as u32;
        }
        assert!(hits > 0, "round {round}: warmed cache never hit");
        assert!(cache.len() <= pool.len());
    }
}

#[test]
fn cache_serves_all_packet_sources_from_one_entry() {
    // The memo stores the lookup, not the decision: a key cached via a
    // link-entered packet must still drop when locally injected.
    let table = RoutingTable::new(); // empty: nothing matches
    let mut cache = RouteCache::new();
    let (d1, hit1) = cache.route(&table, 42, PacketSource::Link(Direction::West));
    assert!(!hit1);
    assert_eq!(d1, table.route_packet(42, PacketSource::Link(Direction::West)));
    let (d2, hit2) = cache.route(&table, 42, PacketSource::Local(3));
    assert!(hit2, "same key, different source must still hit");
    assert_eq!(d2, table.route_packet(42, PacketSource::Local(3)));
    assert_ne!(d1, d2, "decision still depends on the packet source");
}

// ---------------------------------------------------------------------------
// bucketed vs heap event ordering

#[test]
fn calendar_and_heap_dispatch_identically_on_seeded_storms() {
    // The heap is the legacy fabric's ordering by construction; drive
    // both queues with the same seeded storm of (time, id) pushes —
    // including heavy same-timestamp fan-out — and require the exact
    // same pop sequence.
    for seed in [3u64, 0xBEEF, 0x5EED_E11] {
        let mut rng = SplitMix64::new(seed);
        let mut cal: CalendarQueue<u64> = CalendarQueue::new();
        let mut heap: HeapQueue<u64> = HeapQueue::new();
        let mut now = 0u64;
        let mut id = 0u64;
        let mut popped = 0usize;
        for _ in 0..20_000 {
            if rng.next_f64() < 0.55 || cal.is_empty() {
                let delta = match rng.below(8) {
                    0..=2 => 0,                               // same-cycle fan-out
                    3..=4 => 100 + rng.next_u64() % 700,      // router/link latencies
                    5 => 1_000_000,                           // a timer tick away
                    6 => rng.next_u64() % 300_000,            // drop waits, UDP frames
                    _ => 30_000_000 + rng.next_u64() % 1_000_000_000, // overflow territory
                };
                cal.push(now + delta, id);
                heap.push(now + delta, id);
                id += 1;
            } else {
                let a = cal.pop().unwrap();
                let b = heap.pop().unwrap();
                assert_eq!(a, b, "seed {seed}: dispatch diverged after {popped} pops");
                now = a.0;
                popped += 1;
            }
        }
        while let Some(a) = cal.pop() {
            assert_eq!(Some(a), heap.pop(), "seed {seed}: tail diverged");
        }
        assert!(heap.pop().is_none());
    }
}

// ---------------------------------------------------------------------------
// whole-workload equivalence

#[test]
fn conway_run_identical_across_fabrics() {
    let run = |mode: FabricMode| {
        let mut tools =
            SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn5).with_fabric(mode)).unwrap();
        let ids =
            build_conway_grid(&mut tools, 12, 12, &[(5, 4), (5, 5), (5, 6), (4, 5)]).unwrap();
        tools.run_ticks(8).unwrap();
        let recordings: Vec<Vec<u8>> =
            ids.iter().map(|id| tools.recording(*id).to_vec()).collect();
        let sim = tools.sim_mut().unwrap();
        let stats = sim.stats;
        let routers = sim.total_router_stats().semantic();
        let time = sim.now_ns();
        let dropped = tools.provenance().total_dropped();
        (recordings, stats, routers, time, dropped)
    };
    let fast = run(FabricMode::Fast);
    let legacy = run(FabricMode::Legacy);
    assert_eq!(fast.0, legacy.0, "cell recordings differ");
    assert_eq!(fast.1, legacy.1, "sim stats differ");
    assert_eq!(fast.2, legacy.2, "router stats differ");
    assert_eq!(fast.3, legacy.3, "virtual time differs");
    assert_eq!(fast.4, legacy.4);
    // And the run actually produced traffic.
    assert!(fast.1.mc_sent > 0);
}

#[test]
fn microcircuit_storm_identical_across_fabrics() {
    // The full E8 microcircuit needs the pjrt artifacts; the storm
    // probe drives the identical mapped topology (placements, keys,
    // compressed tables) with deterministic pure-Rust traffic.
    let fast = run_fabric_probe(
        ProbeWorkload::MicrocircuitStorm { scale: 0.03, boards: 1 },
        6,
        FabricMode::Fast,
    )
    .unwrap();
    let legacy = run_fabric_probe(
        ProbeWorkload::MicrocircuitStorm { scale: 0.03, boards: 1 },
        6,
        FabricMode::Legacy,
    )
    .unwrap();
    assert_eq!(fast.digest, legacy.digest, "storm behaviour diverged");
    assert_eq!(fast.events, legacy.events);
    assert_eq!(fast.hops, legacy.hops);
    assert_eq!(fast.mc_sent, legacy.mc_sent);
    assert_eq!(fast.mc_delivered, legacy.mc_delivered);
    assert_eq!(
        (fast.dropped, fast.reinjected, fast.lost_forever),
        (legacy.dropped, legacy.reinjected, legacy.lost_forever)
    );
    assert!(fast.mc_sent > 0, "storm generated no traffic");
    assert_eq!((legacy.cache_hits, legacy.cache_misses), (0, 0));
    assert!(fast.cache_hits > 0, "fast fabric never hit its route cache");
}
