//! Experiment E12 — bulk data plane correctness: seeded frame-loss
//! recovery on both directions, multi-board fast ≡ SCAMP equivalence,
//! and the simulated-time concurrency of per-board streams.

use std::collections::BTreeMap;

use spinntools::front::{DataPlaneOptions, FastPath};
use spinntools::machine::{ChipCoord, Machine, MachineBuilder};
use spinntools::simulator::{scamp, SimConfig, SimMachine};
use spinntools::util::{fnv1a_64, SplitMix64};

fn picker() -> impl FnMut(ChipCoord) -> Option<u8> {
    let mut used: BTreeMap<ChipCoord, u8> = BTreeMap::new();
    move |chip| {
        let next = used.entry(chip).or_insert(17);
        let c = *next;
        *next -= 1;
        Some(c)
    }
}

fn pattern(len: usize, seed: u64) -> Vec<u8> {
    let mut rng = SplitMix64::new(seed);
    (0..len).map(|_| (rng.next_u64() & 0xff) as u8).collect()
}

/// Seeded ~1-in-`denom` frame dropper that only afflicts the first
/// attempt, so re-requests always recover.
fn lossy(seed: u64, denom: u64) -> impl FnMut(u32, u32) -> bool {
    let mut s = seed;
    move |_seq, attempt| {
        if attempt > 0 {
            return false;
        }
        s = s
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        (s >> 33) % denom == 0
    }
}

/// Two chips per board of a 3-board (one-triad) toroid.
fn chips_per_board(machine: &Machine, per_board: usize) -> Vec<ChipCoord> {
    let mut out = Vec::new();
    for eth in machine.ethernet_chips() {
        let eth = (eth.x, eth.y);
        out.extend(
            machine
                .chip_coords()
                .filter(|c| machine.nearest_ethernet(*c) == Some(eth))
                .take(per_board),
        );
    }
    out
}

#[test]
fn data_in_loss_recovers_byte_identical() {
    let m = MachineBuilder::spinn5().build();
    let mut sim = SimMachine::boot(m, SimConfig::default());
    let chip = (7, 7);
    let data = pattern(100_000, 0xD47A);
    let addr = scamp::alloc_sdram(&mut sim, chip, data.len() as u32).unwrap();
    let fp = FastPath::install(&mut sim, &[chip], picker(), &DataPlaneOptions::default()).unwrap();
    scamp::signal_start(&mut sim).unwrap();
    let stats = fp
        .write_with_loss(&mut sim, chip, addr, &data, lossy(17, 4))
        .unwrap();
    assert!(stats.frames_resent > 0, "loss injection never triggered");
    let got = scamp::read_sdram(&mut sim, chip, addr, data.len()).unwrap();
    assert_eq!(fnv1a_64(&got), fnv1a_64(&data));
    assert_eq!(got, data, "recovered image differs from the source");
}

#[test]
fn extraction_loss_recovers_byte_identical() {
    let m = MachineBuilder::spinn5().build();
    let mut sim = SimMachine::boot(m, SimConfig::default());
    let chip = (5, 6);
    let data = pattern(100_000, 0x0D0A);
    let addr = scamp::alloc_sdram(&mut sim, chip, data.len() as u32).unwrap();
    scamp::write_sdram(&mut sim, chip, addr, &data).unwrap();
    let fp = FastPath::install(&mut sim, &[chip], picker(), &DataPlaneOptions::default()).unwrap();
    scamp::signal_start(&mut sim).unwrap();
    let got = fp
        .read_with_loss(&mut sim, chip, addr, data.len(), lossy(23, 4))
        .unwrap();
    assert_eq!(got, data, "recovered read differs from SDRAM");
    // The reader must actually have streamed extra (re-requested) frames.
    let reader = fp.reader_of(chip).unwrap();
    let streamed = *scamp::provenance(&sim, reader)
        .unwrap()
        .get("words_streamed")
        .unwrap();
    assert!(
        streamed > data.len().div_ceil(4) as u64,
        "no re-requested frames were streamed ({streamed} words)"
    );
}

#[test]
fn multi_board_extraction_matches_scamp() {
    // Fast path ≡ SCAMP path on a multi-board (one-triad, 3-board)
    // machine, with chips on every board.
    let m = MachineBuilder::triads(1, 1).build();
    let mut sim = SimMachine::boot(m.clone(), SimConfig::default());
    let chips = chips_per_board(&m, 2);
    assert_eq!(chips.len(), 6);
    let mut reqs = Vec::new();
    let mut datas = Vec::new();
    for (i, chip) in chips.iter().enumerate() {
        let data = pattern(48_000 + 321 * i, 0xBEEF + i as u64);
        let addr = scamp::alloc_sdram(&mut sim, *chip, data.len() as u32).unwrap();
        scamp::write_sdram(&mut sim, *chip, addr, &data).unwrap();
        reqs.push((*chip, addr, data.len()));
        datas.push(data);
    }
    let fp = FastPath::install(&mut sim, &chips, picker(), &DataPlaneOptions::default()).unwrap();
    assert_eq!(fp.n_boards(), 3, "a gatherer on every board");
    scamp::signal_start(&mut sim).unwrap();
    let fast = fp.read_many(&mut sim, &reqs).unwrap();
    for (((chip, addr, len), fast), src) in reqs.iter().zip(&fast).zip(&datas) {
        let slow = scamp::read_sdram(&mut sim, *chip, *addr, *len).unwrap();
        assert_eq!(fnv1a_64(fast), fnv1a_64(&slow), "fast ≠ scamp on {chip:?}");
        assert_eq!(fast, src, "fast read corrupted {chip:?}");
    }
}

#[test]
fn multi_board_streams_overlap_in_simulated_time() {
    // One transfer per board: read_many must cost roughly one board's
    // stream time, not three — the E12 scaling claim at test scale.
    let m = MachineBuilder::triads(1, 1).build();
    let len = 64 * 1024;
    let setup = |sim: &mut SimMachine| -> (FastPath, Vec<(ChipCoord, u32, usize)>) {
        let chips = chips_per_board(&sim.machine, 1);
        let mut reqs = Vec::new();
        for chip in &chips {
            let data = pattern(len, 0xCAFE);
            let addr = scamp::alloc_sdram(sim, *chip, len as u32).unwrap();
            scamp::write_sdram(sim, *chip, addr, &data).unwrap();
            reqs.push((*chip, addr, len));
        }
        let fp = FastPath::install(sim, &chips, picker(), &DataPlaneOptions::default()).unwrap();
        scamp::signal_start(sim).unwrap();
        (fp, reqs)
    };

    let mut par_sim = SimMachine::boot(m.clone(), SimConfig::default());
    let (fp, reqs) = setup(&mut par_sim);
    let t0 = par_sim.now_ns();
    fp.read_many(&mut par_sim, &reqs).unwrap();
    let t_parallel = par_sim.now_ns() - t0;

    let mut ser_sim = SimMachine::boot(m, SimConfig::default());
    let (fp, reqs) = setup(&mut ser_sim);
    let t0 = ser_sim.now_ns();
    for (chip, addr, len) in &reqs {
        fp.read(&mut ser_sim, *chip, *addr, *len).unwrap();
    }
    let t_serial = ser_sim.now_ns() - t0;

    assert!(
        t_parallel * 10 < t_serial * 6,
        "3-board extraction did not overlap: parallel {t_parallel} ns vs serial {t_serial} ns"
    );
}

#[test]
fn multi_board_loading_matches_scamp_and_overlaps() {
    let m = MachineBuilder::triads(1, 1).build();
    let mut sim = SimMachine::boot(m, SimConfig::default());
    let chips = chips_per_board(&sim.machine, 1);
    let len = 64 * 1024;
    let datas: Vec<Vec<u8>> = (0..chips.len())
        .map(|i| pattern(len, 0xF00D + i as u64))
        .collect();
    let addrs: Vec<u32> = chips
        .iter()
        .map(|c| scamp::alloc_sdram(&mut sim, *c, len as u32).unwrap())
        .collect();
    let fp = FastPath::install(&mut sim, &chips, picker(), &DataPlaneOptions::default()).unwrap();
    scamp::signal_start(&mut sim).unwrap();

    // Parallel multi-board load…
    let reqs: Vec<(ChipCoord, u32, &[u8])> = chips
        .iter()
        .zip(&addrs)
        .zip(&datas)
        .map(|((c, a), d)| (*c, *a, d.as_slice()))
        .collect();
    let t0 = sim.now_ns();
    fp.write_many(&mut sim, &reqs).unwrap();
    let t_parallel = sim.now_ns() - t0;
    for ((chip, addr), data) in chips.iter().zip(&addrs).zip(&datas) {
        let got = scamp::read_sdram(&mut sim, *chip, *addr, len).unwrap();
        assert_eq!(fnv1a_64(&got), fnv1a_64(data), "load corrupted {chip:?}");
    }

    // …versus the same transfers one at a time.
    let t0 = sim.now_ns();
    for ((chip, addr), data) in chips.iter().zip(&addrs).zip(&datas) {
        fp.write(&mut sim, *chip, *addr, data).unwrap();
    }
    let t_serial = sim.now_ns() - t0;
    assert!(
        t_parallel * 10 < t_serial * 6,
        "3-board loading did not overlap: parallel {t_parallel} ns vs serial {t_serial} ns"
    );
}

#[test]
fn fast_data_in_beats_batched_scamp_3x() {
    // The E12 acceptance shape at test scale, on a far chip.
    let m = MachineBuilder::spinn5().build();
    let mut sim = SimMachine::boot(m, SimConfig::default());
    let chip = (7, 7);
    let data = pattern(64 * 1024, 0x3A3A);
    let a = scamp::alloc_sdram(&mut sim, chip, data.len() as u32).unwrap();
    let b = scamp::alloc_sdram(&mut sim, chip, data.len() as u32).unwrap();
    let fp = FastPath::install(&mut sim, &[chip], picker(), &DataPlaneOptions::default()).unwrap();
    scamp::signal_start(&mut sim).unwrap();

    let t0 = sim.now_ns();
    scamp::write_sdram_batched(&mut sim, chip, a, &data).unwrap();
    let t_batched = sim.now_ns() - t0;

    let t1 = sim.now_ns();
    fp.write(&mut sim, chip, b, &data).unwrap();
    let t_fast = sim.now_ns() - t1;

    assert!(
        t_fast * 3 <= t_batched,
        "fast data-in {t_fast} ns vs batched SCAMP {t_batched} ns"
    );
    assert_eq!(
        scamp::read_sdram(&mut sim, chip, a, data.len()).unwrap(),
        scamp::read_sdram(&mut sim, chip, b, data.len()).unwrap(),
        "the two write paths disagree"
    );
}
