//! Conway's Game of Life at scale (§7.1, Figure 13 / experiment E7).
//!
//! A glider gun-free but busy random board, one cell per core across a
//! simulated SpiNN-5 board, with state recorded every timestep and
//! extracted through the fast multicast protocol. Prints the board
//! animation and per-phase statistics.
//!
//! ```sh
//! cargo run --release --example conway_life -- [rows cols steps]
//! ```

use spinntools::apps::networks::build_conway_grid;
use spinntools::front::{ExtractionMethod, MachineSpec, SpiNNTools, ToolsConfig};
use spinntools::util::SplitMix64;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let rows: u32 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(20);
    let cols: u32 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(20);
    let steps: u64 = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(32);

    // Random primordial soup, ~35% alive.
    let mut rng = SplitMix64::new(2026);
    let live: Vec<(u32, u32)> = (0..rows)
        .flat_map(|r| (0..cols).map(move |c| (r, c)))
        .filter(|_| rng.next_f64() < 0.35)
        .collect();

    let spec = if rows * cols <= 3 * 17 {
        MachineSpec::Spinn3
    } else {
        MachineSpec::Spinn5
    };
    let mut tools = SpiNNTools::new(
        ToolsConfig::new(spec).with_extraction(ExtractionMethod::FastMulticast),
    )?;
    let t0 = std::time::Instant::now();
    let ids = build_conway_grid(&mut tools, rows, cols, &live)?;
    println!(
        "graph: {} vertices, {} edges",
        rows * cols,
        tools_edges(rows, cols)
    );

    tools.run_ticks(steps)?;
    let wall = t0.elapsed();

    // Reassemble and draw a few generations.
    for t in [0usize, (steps / 2) as usize - 1, steps as usize - 1] {
        println!("generation {t}:");
        for r in 0..rows {
            let row: String = (0..cols)
                .map(|c| {
                    let rec = tools.recording(ids[(r * cols + c) as usize]);
                    if rec.get(t).copied().unwrap_or(0) == 1 { '#' } else { '.' }
                })
                .collect();
            println!("  {row}");
        }
    }

    let alive_final: usize = ids
        .iter()
        .map(|id| *tools.recording(*id).last().unwrap_or(&0) as usize)
        .sum();
    let prov = tools.provenance();
    let mapping = tools.mapping().unwrap();
    println!("--- stats ---");
    println!("chips used:        {}", mapping.placements.used_chips().len());
    println!("routing entries:   {}", mapping.tables.values().map(|t| t.len()).sum::<usize>());
    println!("alive at end:      {alive_final} / {}", rows * cols);
    println!("packets sent:      {}", tools.sim_mut().map(|s| s.stats.mc_sent).unwrap_or(0));
    println!("packets dropped:   {}", prov.total_dropped());
    println!("missed phases:     {}", prov.counter_total("missed_neighbour_states"));
    println!("host wall time:    {wall:.2?} for {steps} simulated ticks");
    tools.stop()?;
    Ok(())
}

fn tools_edges(rows: u32, cols: u32) -> u32 {
    // 8-neighbourhood, directed: count pairs.
    let mut n = 0;
    for r in 0..rows as i64 {
        for c in 0..cols as i64 {
            for dr in -1..=1i64 {
                for dc in -1..=1i64 {
                    if (dr, dc) == (0, 0) {
                        continue;
                    }
                    let (nr, nc) = (r + dr, c + dc);
                    if nr >= 0 && nc >= 0 && nr < rows as i64 && nc < cols as i64 {
                        n += 1;
                    }
                }
            }
        }
    }
    n
}
