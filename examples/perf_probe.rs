//! Perf probe (EXPERIMENTS.md §Perf): micro-measurements of the three
//! hot paths — PJRT kernel dispatch (L1/L2), the DES router loop and
//! core-callback machinery (L3), and TCAM lookup.

use std::time::Instant;

use spinntools::machine::router::{Route, RoutingEntry, RoutingTable};
use spinntools::machine::{CoreLocation, Direction, MachineBuilder};
use spinntools::runtime::{HostTensor, Runtime};
use spinntools::simulator::{scamp, CoreApp, CoreCtx, SimConfig, SimMachine};

fn main() -> anyhow::Result<()> {
    // 1. PJRT dispatch latency per model.
    if let Ok(rt) = Runtime::open_default() {
        for model in [
            "lif_step_n64",
            "lif_step_n256",
            "lif_step_packed_n256",
            "conway_step_32x32",
            "poisson_step_n256",
        ] {
            let shapes = rt.input_shapes(model)?;
            let inputs: Vec<HostTensor> = shapes
                .iter()
                .map(|s| {
                    let n: usize = s.iter().product();
                    if model.starts_with("conway") {
                        HostTensor::I32(vec![0; n])
                    } else if s.is_empty() {
                        HostTensor::ScalarF32(0.5)
                    } else {
                        HostTensor::F32(vec![0.0; n])
                    }
                })
                .collect();
            rt.exec(model, &inputs)?; // warm (compile)
            let n_iters = 500;
            let t = Instant::now();
            for _ in 0..n_iters {
                rt.exec(model, &inputs)?;
            }
            println!("pjrt_exec {model:<20} {:>10.2?}/call", t.elapsed() / n_iters);
        }
    }

    // 2. TCAM lookup (1024-entry worst case, last-entry match).
    let entries: Vec<RoutingEntry> = (0..1024)
        .map(|k| RoutingEntry::new(k, !0, Route::EMPTY.with_processor(1)))
        .collect();
    let table = RoutingTable::from_entries(entries);
    let t = Instant::now();
    let n = 1_000_000u32;
    let mut acc = 0u64;
    for i in 0..n {
        if table.lookup(1023 - (i & 1)).is_some() {
            acc += 1;
        }
    }
    println!("tcam_lookup worst-case    {:>10.2?}/lookup (acc {acc})", t.elapsed() / n);

    // 3. DES packet storm: one sender flooding a 3-hop path, no apps work.
    struct Flood;
    impl CoreApp for Flood {
        fn on_timer(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
            for _ in 0..1000 {
                ctx.send_mc(7, Some(1));
            }
            Ok(())
        }
    }
    struct Sink;
    impl CoreApp for Sink {
        fn on_timer(&mut self, _: &mut CoreCtx) -> anyhow::Result<()> {
            Ok(())
        }
        fn on_mc_packet(&mut self, _: u32, _: Option<u32>, _: &mut CoreCtx) -> anyhow::Result<()> {
            Ok(())
        }
    }
    let m = MachineBuilder::spinn5().build();
    let mut sim = SimMachine::boot(m, SimConfig::default());
    // Route key 7 from (0,0) east 3 hops to (3,0) core 1.
    for x in 0..3u32 {
        scamp::load_routing_table(
            &mut sim,
            (x, 0),
            RoutingTable::from_entries(vec![RoutingEntry::new(
                7,
                !0,
                Route::EMPTY.with_link(Direction::East),
            )]),
        )?;
    }
    scamp::load_routing_table(
        &mut sim,
        (3, 0),
        RoutingTable::from_entries(vec![RoutingEntry::new(
            7,
            !0,
            Route::EMPTY.with_processor(1),
        )]),
    )?;
    scamp::load_app(&mut sim, CoreLocation::new(0, 0, 1), Box::new(Flood), Default::default(), Default::default())?;
    scamp::load_app(&mut sim, CoreLocation::new(3, 0, 1), Box::new(Sink), Default::default(), Default::default())?;
    scamp::signal_start(&mut sim)?;
    let ticks = 100u64;
    let t = Instant::now();
    sim.start_run_cycle(ticks);
    sim.run_until_idle()?;
    let dt = t.elapsed();
    let events = sim.stats.events_processed;
    println!(
        "des_storm {} events in {:.2?} = {:>8.0} ns/event ({} pkts delivered)",
        events,
        dt,
        dt.as_nanos() as f64 / events as f64,
        sim.stats.mc_delivered
    );

    // 4. Core-callback overhead: deliver directly to a local core.
    let m = MachineBuilder::spinn3().build();
    let mut sim = SimMachine::boot(m, SimConfig::default());
    scamp::load_routing_table(
        &mut sim,
        (0, 0),
        RoutingTable::from_entries(vec![RoutingEntry::new(
            7,
            !0,
            Route::EMPTY.with_processor(2),
        )]),
    )?;
    scamp::load_app(&mut sim, CoreLocation::new(0, 0, 1), Box::new(Flood), Default::default(), Default::default())?;
    scamp::load_app(&mut sim, CoreLocation::new(0, 0, 2), Box::new(Sink), Default::default(), Default::default())?;
    scamp::signal_start(&mut sim)?;
    let t = Instant::now();
    sim.start_run_cycle(100);
    sim.run_until_idle()?;
    let dt = t.elapsed();
    println!(
        "local_deliver {} events in {:.2?} = {:>8.0} ns/event",
        sim.stats.events_processed,
        dt,
        dt.as_nanos() as f64 / sim.stats.events_processed as f64
    );
    Ok(())
}
// (packed-variant probe appended during the perf pass)
