//! **The end-to-end validation driver** (§7.2, Figure 14 / experiment
//! E8): a scaled Potjans–Diesmann cortical microcircuit — 8 LIF
//! populations with the paper's connectivity map, each driven by Poisson
//! background — built as an *application graph*, split onto a simulated
//! SpiNN-5 machine, executed with the AOT-compiled Pallas LIF kernel on
//! every neuron core via PJRT, spikes recorded and extracted, and
//! per-population firing rates reported.
//!
//! All three layers compose here: L1 Pallas `lif_step` (validated vs
//! ref.py) → L2 JAX model → HLO artifact → L3 rust toolchain + machine.
//!
//! ```sh
//! make artifacts && cargo run --release --example microcircuit -- [scale] [run_ms]
//! ```

use spinntools::apps::networks::{build_microcircuit, firing_rates, PD_POPULATIONS};
use spinntools::front::{MachineSpec, SpiNNTools, ToolsConfig};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let scale: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(0.02);
    let run_ms: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(200);

    let spec = if scale > 0.05 {
        MachineSpec::Boards(3)
    } else {
        MachineSpec::Spinn5
    };
    let mut tools = SpiNNTools::new(ToolsConfig::new(spec).with_artifacts())?;

    let t_build = std::time::Instant::now();
    let circuit = build_microcircuit(&mut tools, scale, 20260710, true)?;
    let n_total: u32 = circuit.sizes.values().sum();
    println!(
        "microcircuit at scale {scale}: {n_total} neurons in 8 populations (+{n_total} Poisson sources)"
    );

    let t_run = std::time::Instant::now();
    tools.run_ms(run_ms)?;
    let run_wall = t_run.elapsed();

    // --- the paper-style report -----------------------------------------
    let rates = firing_rates(&tools, &circuit, run_ms as f64);
    println!("\nper-population firing rates after {run_ms} ms:");
    println!("  {:>6} {:>8} {:>10} {:>10}", "pop", "neurons", "rate (Hz)", "PD ref");
    // Potjans & Diesmann 2014 Fig. 6 reference rates (spontaneous).
    let pd_ref = [0.86, 2.96, 4.45, 5.93, 7.59, 8.61, 1.09, 7.69];
    for (i, name) in PD_POPULATIONS.iter().enumerate() {
        println!(
            "  {:>6} {:>8} {:>10.2} {:>10.2}",
            name, circuit.sizes[name], rates[name], pd_ref[i]
        );
    }

    let prov = tools.provenance();
    let sim_stats = tools.sim_mut().map(|s| s.stats).unwrap();
    let mapping = tools.mapping().unwrap();
    println!("\n--- systems report ---");
    println!("build+map+load wall:  {:.2?}", t_build.elapsed() - run_wall);
    println!("run wall:             {run_wall:.2?} ({run_ms} simulated ms)");
    println!("cores used:           {}", mapping.placements.len());
    println!("chips used:           {}", mapping.placements.used_chips().len());
    println!(
        "routing entries:      {} across {} chips",
        mapping.tables.values().map(|t| t.len()).sum::<usize>(),
        mapping.tables.len()
    );
    println!("spikes delivered:     {}", prov.counter_total("spikes_in"));
    println!("spikes emitted:       {}", prov.counter_total("spikes_out"));
    println!("packets sent:         {}", sim_stats.mc_sent);
    println!("packets dropped:      {}", prov.total_dropped());
    println!("packets reinjected:   {}", prov.total_reinjected());
    println!(
        "HLO kernel execs:     {}",
        tools.runtime().map(|r| r.execs.get()).unwrap_or(0)
    );
    if !prov.anomalies.is_empty() {
        println!("anomalies:");
        for a in prov.anomalies.iter().take(10) {
            println!("  - {a}");
        }
    }
    tools.stop()?;
    Ok(())
}
