//! Quickstart: the complete Figure-8 flow in ~40 lines.
//!
//! Builds a small Conway machine graph (§7.1), maps it onto a simulated
//! SpiNN-3 board, runs it, and reads back the recorded states.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use spinntools::apps::networks::build_conway_grid;
use spinntools::front::{MachineSpec, SpiNNTools, ToolsConfig};

fn main() -> anyhow::Result<()> {
    // Setup (§6.1): a virtual 4-chip SpiNN-3 board.
    let mut tools = SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn3))?;

    // Graph creation (§6.2): a 5x5 Life board with a blinker.
    let ids = build_conway_grid(&mut tools, 5, 5, &[(2, 1), (2, 2), (2, 3)])?;

    // Graph execution (§6.3): discover, map, load, run 8 timesteps.
    tools.run_ticks(8)?;

    // Results (§6.4): recorded state per cell per timestep.
    println!("generation-by-generation board (row 2 shown per tick):");
    for tick in 0..8 {
        let row: String = (0..5)
            .map(|c| {
                let rec = tools.recording(ids[2 * 5 + c]);
                if rec[tick] == 1 { '#' } else { '.' }
            })
            .collect();
        println!("  t={tick}: {row}");
    }

    // Provenance (§6.3.5).
    let prov = tools.provenance();
    println!(
        "packets: {} sent, {} dropped; anomalies: {}",
        tools.sim_mut().map(|s| s.stats.mc_sent).unwrap_or(0),
        prov.total_dropped(),
        prov.anomalies.len()
    );

    // Where things were placed (the mapping database of §6.3.2).
    let db = tools.database().unwrap();
    println!(
        "cell_2_2 runs on core {}",
        db.placement_of("cell_2_2").unwrap()
    );
    tools.stop()?;
    Ok(())
}
