//! Live interaction (§6.9, Figure 12 / experiment E6): tap a running
//! simulation's multicast streams with the Live Packet Gatherer and
//! inject external events through the Reverse IP Tag Multicast Source —
//! both wired up by nothing more than graph edges.
//!
//! ```sh
//! cargo run --release --example live_io
//! ```

use spinntools::apps::conway::STATE_PARTITION;
use spinntools::apps::gatherer::LivePacketGathererVertex;
use spinntools::apps::networks::build_conway_grid;
use spinntools::apps::reverse_source::{ReverseIpTagSourceVertex, OUT_PARTITION};
use spinntools::front::{LiveEventListener, LiveInjector, MachineSpec, SpiNNTools, ToolsConfig};

fn main() -> anyhow::Result<()> {
    let mut tools = SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn3))?;

    // A glider on a 6x6 board.
    let ids = build_conway_grid(
        &mut tools,
        6,
        6,
        &[(0, 1), (1, 2), (2, 0), (2, 1), (2, 2)],
    )?;

    // Live output: LPG on the Ethernet chip; tap the whole middle row by
    // adding one edge per cell (Figure 12 top).
    let lpg = tools.add_machine_vertex(LivePacketGathererVertex::arc(
        "lpg", "viz-host", 19999, (0, 0),
    ))?;
    for c in 0..6 {
        tools.add_machine_edge(ids[2 * 6 + c], lpg, STATE_PARTITION)?;
    }

    // Live input: a RIPTMS that can poke the corner cells.
    let riptms = tools.add_machine_vertex(ReverseIpTagSourceVertex::arc("poker", 18888, 4))?;
    tools.add_machine_edge(riptms, ids[0], OUT_PARTITION)?;
    tools.add_machine_edge(riptms, ids[5], OUT_PARTITION)?;

    // Run a first window; the mapping database tells the listener how to
    // decode keys (Figure 8's notification handshake).
    tools.run_ticks(6)?;
    let db = tools.database().unwrap().clone();
    let listener = LiveEventListener::new(19999, db);
    let events = listener.poll(tools.sim_mut().unwrap())?;
    println!("live events from the middle row ({} total):", events.len());
    let mut by_vertex: std::collections::BTreeMap<String, Vec<u32>> = Default::default();
    for e in &events {
        by_vertex
            .entry(e.vertex().to_string())
            .or_default()
            .push(e.payload.unwrap_or(0));
    }
    for (v, states) in &by_vertex {
        let s: String = states.iter().map(|x| if *x == 1 { '#' } else { '.' }).collect();
        println!("  {v}: {s}");
    }

    // Inject events into the corners, then resume.
    let injector = LiveInjector::new((0, 0), 18888);
    injector.send(tools.sim_mut().unwrap(), &[0, 1])?;
    tools.sim_mut().unwrap().run_until_idle()?;
    tools.run_ticks(4)?;

    let prov = tools.provenance();
    println!("events forwarded by LPG: {}", prov.counter_total("events_forwarded"));
    println!("events injected by RIPTMS: {}", prov.counter_total("events_injected"));
    tools.stop()?;
    Ok(())
}
