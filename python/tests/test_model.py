"""L2 model + AOT path tests: artifact lowering, shapes, HLO sanity."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import to_hlo_text
from compile.kernels.ref import N_PARAMS, lif_step_ref
from compile.model import (
    artifact_specs,
    conway_tile_step,
    lif_population_step,
    poisson_thinning_step,
)


class TestArtifactSpecs:
    def test_all_specs_lower_to_hlo_text(self):
        for name, fn, args in artifact_specs():
            text = to_hlo_text(jax.jit(fn).lower(*args))
            assert text.startswith("HloModule"), name
            assert "ENTRY" in text, name

    def test_spec_names_unique(self):
        names = [n for n, _, _ in artifact_specs()]
        assert len(names) == len(set(names))

    def test_lif_variants_cover_expected_sizes(self):
        names = {n for n, _, _ in artifact_specs()}
        assert {"lif_step_n64", "lif_step_n128", "lif_step_n256"} <= names
        assert {"conway_step_16x16", "conway_step_32x32",
                "conway_step_64x64"} <= names

    def test_manifest_matches_runtime_contract(self, tmp_path):
        """aot.py --out must emit one .hlo.txt per spec plus manifest.json
        whose shapes match the spec example args (the rust runtime trusts
        this manifest)."""
        from compile import aot
        import sys
        argv = sys.argv
        sys.argv = ["aot", "--out", str(tmp_path)]
        try:
            aot.main()
        finally:
            sys.argv = argv
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        specs = {n: args for n, _, args in artifact_specs()}
        assert set(manifest) == set(specs)
        for name, entry in manifest.items():
            assert (tmp_path / entry["file"]).exists()
            got_shapes = [tuple(i["shape"]) for i in entry["inputs"]]
            assert got_shapes == [a.shape for a in specs[name]]


class TestLifPopulationStep:
    def test_matches_ref(self):
        rng = np.random.default_rng(0)
        n = 128
        state = [jnp.asarray(rng.uniform(-70, -50, n), jnp.float32)] + [
            jnp.asarray(rng.uniform(0, 5, n), jnp.float32) for _ in range(5)
        ]
        params = jnp.array([0.9, 0.1, 0.1, -65.0, -65.0, -50.0, 2.0, 0.0],
                           jnp.float32)
        got = lif_population_step(*state, params)
        want = lif_step_ref(*state, params)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=1e-5, atol=1e-5)

    def test_n_outputs(self):
        n = 64
        z = jnp.zeros(n, jnp.float32)
        p = jnp.zeros(N_PARAMS, jnp.float32)
        assert len(lif_population_step(z, z, z, z, z, z, p)) == 5


class TestPoisson:
    def test_thinning_rate(self):
        rng = np.random.default_rng(1)
        unif = jnp.asarray(rng.uniform(0, 1, 100_000), jnp.float32)
        (spikes,) = poisson_thinning_step(unif, jnp.float32(0.01))
        rate = float(np.asarray(spikes).mean())
        assert 0.008 < rate < 0.012

    def test_zero_rate_never_spikes(self):
        unif = jnp.asarray(np.random.default_rng(2).uniform(0, 1, 1000),
                           jnp.float32)
        (spikes,) = poisson_thinning_step(unif, jnp.float32(0.0))
        assert not np.any(np.asarray(spikes))


class TestConwayTileStep:
    def test_returns_tuple(self):
        out = conway_tile_step(jnp.zeros((16, 16), jnp.int32))
        assert isinstance(out, tuple) and len(out) == 1


class TestHloProperties:
    def test_lif_hlo_has_no_custom_calls(self):
        """interpret=True must lower to plain HLO the CPU PJRT client can
        run — a Mosaic custom-call here would break the rust runtime."""
        _, fn, args = next(s for s in artifact_specs()
                           if s[0] == "lif_step_n256")
        text = to_hlo_text(jax.jit(fn).lower(*args))
        assert "custom-call" not in text

    def test_conway_hlo_has_no_custom_calls(self):
        _, fn, args = next(s for s in artifact_specs()
                           if s[0] == "conway_step_32x32")
        text = to_hlo_text(jax.jit(fn).lower(*args))
        assert "custom-call" not in text


class TestPackedLif:
    def test_packed_matches_unpacked(self):
        import jax
        from compile.model import lif_population_step_packed

        rng = np.random.default_rng(3)
        n = 128
        state = jnp.asarray(rng.uniform(-70, 5, (6, n)), jnp.float32)
        params = jnp.array([0.9, 0.1, 0.1, -65.0, -65.0, -50.0, 2.0, 0.0],
                           jnp.float32)
        (packed,) = lif_population_step_packed(state, params)
        unpacked = lif_population_step(*[state[i] for i in range(6)], params)
        assert packed.shape == (5, n)
        for i in range(5):
            np.testing.assert_allclose(np.asarray(packed[i]),
                                       np.asarray(unpacked[i]),
                                       rtol=1e-6, atol=1e-6)

    def test_packed_artifact_registered(self):
        names = {n for n, _, _ in artifact_specs()}
        assert {"lif_step_packed_n64", "lif_step_packed_n128",
                "lif_step_packed_n256"} <= names
