"""Kernel-vs-reference correctness: the CORE L1 signal.

Hypothesis sweeps shapes and state distributions; every Pallas kernel must
match the pure-jnp oracle in ref.py to float tolerance.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.conway import conway_multi_step, conway_step
from compile.kernels.lif import lif_step
from compile.kernels.ref import N_PARAMS, conway_step_ref, lif_step_ref

RTOL = 1e-5
ATOL = 1e-5


def default_params(t_refrac=2.0):
    """Potjans-Diesmann-style LIF constants: tau_m=10ms, tau_syn=0.5ms,
    dt=1ms."""
    return jnp.array(
        [
            np.exp(-1.0 / 10.0),   # alpha_mem
            np.exp(-1.0 / 0.5),    # alpha_syn_e
            np.exp(-1.0 / 0.5),    # alpha_syn_i
            -65.0,                  # v_rest
            -65.0,                  # v_reset
            -50.0,                  # v_thresh
            t_refrac,
            0.0,                    # i_offset
        ],
        dtype=jnp.float32,
    )


def rand_state(rng, n):
    return (
        jnp.asarray(rng.uniform(-80.0, -40.0, n), jnp.float32),   # v
        jnp.asarray(rng.uniform(0.0, 5.0, n), jnp.float32),        # i_exc
        jnp.asarray(rng.uniform(0.0, 5.0, n), jnp.float32),        # i_inh
        jnp.asarray(rng.integers(0, 4, n), jnp.float32),           # refrac
        jnp.asarray(rng.uniform(0.0, 30.0, n), jnp.float32),       # in_exc
        jnp.asarray(rng.uniform(0.0, 10.0, n), jnp.float32),       # in_inh
    )


def assert_lif_matches(state, params, block=256):
    got = lif_step(*state, params, block=block)
    want = lif_step_ref(*state, params)
    for g, w, name in zip(got, want, ["v", "i_exc", "i_inh", "refrac", "spk"]):
        np.testing.assert_allclose(g, w, rtol=RTOL, atol=ATOL, err_msg=name)


class TestLifKernel:
    @pytest.mark.parametrize("n", [64, 128, 256, 512, 1024])
    def test_matches_ref_across_sizes(self, n):
        rng = np.random.default_rng(n)
        assert_lif_matches(rand_state(rng, n), default_params())

    @pytest.mark.parametrize("block", [64, 128, 256])
    def test_block_shape_invariance(self, block):
        """Tiling must not change results: same n, different BlockSpec."""
        rng = np.random.default_rng(7)
        state = rand_state(rng, 512)
        ref = lif_step(*state, default_params(), block=256)
        got = lif_step(*state, default_params(), block=block)
        for g, r in zip(got, ref):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(r))

    def test_spike_then_reset_and_refractory(self):
        params = default_params(t_refrac=3.0)
        v = jnp.array([-49.0] * 64, jnp.float32)  # above threshold already
        zeros = jnp.zeros(64, jnp.float32)
        big = jnp.full(64, 100.0, jnp.float32)
        v1, _, _, rf1, sp1 = lif_step(v, zeros, zeros, zeros, big, zeros,
                                      params, block=64)
        assert np.all(np.asarray(sp1) == 1.0)
        assert np.all(np.asarray(v1) == -65.0)
        assert np.all(np.asarray(rf1) == 3.0)
        # while refractory, even huge input cannot elicit a spike
        v2, _, _, rf2, sp2 = lif_step(v1, zeros, zeros, rf1, big, zeros,
                                      params, block=64)
        assert np.all(np.asarray(sp2) == 0.0)
        assert np.all(np.asarray(v2) == -65.0)
        assert np.all(np.asarray(rf2) == 2.0)

    def test_no_input_decays_to_rest(self):
        params = default_params()
        v = jnp.full(64, -55.0, jnp.float32)
        zeros = jnp.zeros(64, jnp.float32)
        for _ in range(100):
            v, _, _, _, sp = lif_step(v, zeros, zeros, zeros, zeros, zeros,
                                      params, block=64)
            assert not np.any(np.asarray(sp))
        np.testing.assert_allclose(np.asarray(v), -65.0, atol=1e-2)

    def test_inhibition_lowers_potential(self):
        params = default_params()
        zeros = jnp.zeros(64, jnp.float32)
        v = jnp.full(64, -65.0, jnp.float32)
        inh = jnp.full(64, 10.0, jnp.float32)
        v1, _, _, _, _ = lif_step(v, zeros, zeros, zeros, zeros, inh,
                                  params, block=64)
        assert np.all(np.asarray(v1) < -65.0)

    @settings(max_examples=40, deadline=None)
    @given(
        n_blocks=st.integers(1, 4),
        block=st.sampled_from([64, 128, 256]),
        seed=st.integers(0, 2**31 - 1),
        t_refrac=st.floats(0.0, 5.0),
    )
    def test_hypothesis_state_sweep(self, n_blocks, block, seed, t_refrac):
        rng = np.random.default_rng(seed)
        state = rand_state(rng, n_blocks * block)
        assert_lif_matches(state, default_params(t_refrac), block=block)

    def test_refrac_never_negative(self):
        rng = np.random.default_rng(3)
        state = rand_state(rng, 256)
        _, _, _, rf, _ = lif_step(*state, default_params())
        assert np.all(np.asarray(rf) >= 0.0)


def np_conway_ref(board):
    """Independent numpy Life implementation (not jnp) as a second oracle."""
    h, w = board.shape
    padded = np.pad(board, 1)
    neigh = sum(
        padded[1 + dy:1 + dy + h, 1 + dx:1 + dx + w]
        for dy in (-1, 0, 1) for dx in (-1, 0, 1) if (dy, dx) != (0, 0)
    )
    return (((board == 0) & (neigh == 3)) |
            ((board == 1) & ((neigh == 2) | (neigh == 3)))).astype(board.dtype)


class TestConwayKernel:
    @pytest.mark.parametrize("shape", [(4, 4), (16, 16), (32, 32), (64, 64),
                                       (16, 64), (64, 16)])
    def test_matches_ref_across_shapes(self, shape):
        rng = np.random.default_rng(shape[0] * 100 + shape[1])
        board = jnp.asarray(rng.integers(0, 2, shape), jnp.int32)
        np.testing.assert_array_equal(
            np.asarray(conway_step(board)), np.asarray(conway_step_ref(board)))

    def test_matches_independent_numpy_oracle(self):
        rng = np.random.default_rng(42)
        board = rng.integers(0, 2, (32, 32)).astype(np.int32)
        np.testing.assert_array_equal(
            np.asarray(conway_step(jnp.asarray(board))), np_conway_ref(board))

    def test_blinker_oscillates(self):
        board = np.zeros((5, 5), np.int32)
        board[2, 1:4] = 1  # horizontal blinker
        b1 = np.asarray(conway_step(jnp.asarray(board)))
        expect = np.zeros((5, 5), np.int32)
        expect[1:4, 2] = 1  # vertical
        np.testing.assert_array_equal(b1, expect)
        b2 = np.asarray(conway_step(jnp.asarray(b1)))
        np.testing.assert_array_equal(b2, board)

    def test_block_still_life(self):
        board = np.zeros((4, 4), np.int32)
        board[1:3, 1:3] = 1
        b1 = np.asarray(conway_step(jnp.asarray(board)))
        np.testing.assert_array_equal(b1, board)

    def test_glider_translates(self):
        board = np.zeros((8, 8), np.int32)
        board[0, 1] = board[1, 2] = board[2, 0] = board[2, 1] = board[2, 2] = 1
        b = jnp.asarray(board)
        for _ in range(4):  # glider period: 4 steps -> +1,+1 shift
            b = conway_step(b)
        np.testing.assert_array_equal(np.asarray(b), np.roll(board, (1, 1), (0, 1)))

    def test_empty_board_stays_empty(self):
        board = jnp.zeros((16, 16), jnp.int32)
        assert not np.any(np.asarray(conway_step(board)))

    def test_multi_step_equals_repeated_single(self):
        rng = np.random.default_rng(5)
        board = jnp.asarray(rng.integers(0, 2, (16, 16)), jnp.int32)
        fused = conway_multi_step(board, steps=5)
        b = board
        for _ in range(5):
            b = conway_step(b)
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(b))

    @settings(max_examples=40, deadline=None)
    @given(
        h=st.integers(2, 40),
        w=st.integers(2, 40),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shape_sweep(self, h, w, seed):
        rng = np.random.default_rng(seed)
        board = jnp.asarray(rng.integers(0, 2, (h, w)), jnp.int32)
        got = np.asarray(conway_step(board))
        np.testing.assert_array_equal(got, np.asarray(conway_step_ref(board)))
        assert set(np.unique(got)) <= {0, 1}  # invariant: binary board
