"""L2: the per-core compute graphs, calling the L1 Pallas kernels.

Each function here is one "SpiNNaker application binary"'s inner compute,
exactly as a simulated core executes it each timer tick. They are lowered
once by ``aot.py`` to HLO text and loaded by ``rust/src/runtime`` — Python
is never on the run path.

Shapes are fixed at AOT time (one artifact per shape variant, listed in
``ARTIFACTS``); the rust data generator pads state vectors to match.
"""

import jax
import jax.numpy as jnp

from .kernels.conway import conway_step
from .kernels.lif import lif_step
from .kernels.ref import N_PARAMS


def lif_population_step(v, i_exc, i_inh, refrac, in_exc, in_inh, params):
    """One timestep of a LIF population slice (the §7.2 neuron vertex).

    Thin wrapper so the artifact boundary is the whole per-tick compute;
    XLA fuses the Pallas-lowered elementwise graph into a single fusion
    (verified by test_model.py::test_lif_hlo_single_fusion).
    """
    return lif_step(v, i_exc, i_inh, refrac, in_exc, in_inh, params)


def lif_population_step_packed(state, params):
    """The packed variant (EXPERIMENTS.md §Perf): state rows are
    [v, i_exc, i_inh, refrac, in_exc, in_inh] stacked into one f32[6, n]
    tensor, outputs stacked into f32[5, n] ([v', i_exc', i_inh',
    refrac', spiked]).

    Same L1 Pallas kernel inside; packing cuts the PJRT boundary from
    7 in / 5 out buffers to 2 in / 1 out, roughly halving per-call
    dispatch+transfer overhead on the CPU client (measured: 104 us ->
    ~55 us per call at n=256).
    """
    outs = lif_step(state[0], state[1], state[2], state[3], state[4],
                    state[5], params)
    return (jnp.stack(outs),)


def conway_tile_step(board):
    """One timestep of a Conway tile vertex (§7.1 'multiple cells per
    machine vertex' extension)."""
    return (conway_step(board),)


def poisson_thinning_step(unif, rate_per_step):
    """Poisson spike source (§7.2): Bernoulli thinning of pre-drawn
    uniforms — spike iff u < rate*dt. The RNG stream lives in rust (the
    data generator owns seeds, like SpiNNaker's on-core RNG state), so the
    artifact stays deterministic given its inputs.
    """
    return (jnp.where(unif < rate_per_step, 1.0, 0.0),)


def _shape(*dims, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(dims, dtype)


def artifact_specs(n_neurons=256, tile=32):
    """(name, fn, example_args) for every AOT artifact.

    One LIF variant per power-of-two slice width keeps the rust side's
    padding waste under 2x while bounding artifact count.
    """
    specs = []
    for n in (64, 128, 256):
        specs.append((
            f"lif_step_n{n}",
            lif_population_step,
            (
                _shape(n), _shape(n), _shape(n), _shape(n), _shape(n),
                _shape(n), _shape(N_PARAMS),
            ),
        ))
        specs.append((
            f"lif_step_packed_n{n}",
            lif_population_step_packed,
            (_shape(6, n), _shape(N_PARAMS)),
        ))
    for t in (16, 32, 64):
        specs.append((
            f"conway_step_{t}x{t}",
            conway_tile_step,
            (_shape(t, t, dtype=jnp.int32),),
        ))
    for n in (256,):
        specs.append((
            f"poisson_step_n{n}",
            poisson_thinning_step,
            (_shape(n), _shape()),
        ))
    return specs
