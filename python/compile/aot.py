"""AOT compile path: lower every L2 model to HLO text + a manifest.

HLO *text* (not ``HloModuleProto.serialize()``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (behind the rust ``xla`` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Run via ``make artifacts`` (``python -m compile.aot --out ../artifacts``).
Python runs ONCE here; the rust binary is self-contained afterwards.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import artifact_specs


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text, with return_tuple=True so
    the rust side unwraps a single tuple output (xla::Literal::to_tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {}
    for name, fn, example_args in artifact_specs():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [
                {"shape": list(a.shape), "dtype": a.dtype.name}
                for a in example_args
            ],
            "n_outputs": len(fn(*[
                jax.numpy.zeros(a.shape, a.dtype) for a in example_args
            ])),
        }
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
