"""L1 Pallas kernel: LIF population timestep.

The compute hot-spot of the §7.2 use case — one 1 ms update of a slice of
current-based exponential-synapse LIF neurons, as run on every simulated
SpiNNaker core hosting a neuron machine-vertex.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): on SpiNNaker the
neuron state lives in DTCM and synaptic rows are DMA'd in; here the state
vector is tiled into VMEM-resident blocks of ``BLOCK`` lanes via BlockSpec —
the same "working set must fit the scratchpad" discipline. All math is
elementwise (VPU-bound), each state byte is touched exactly once per step,
so the roofline is memory bandwidth, not MXU.

VMEM budget per block (f32): 6 inputs + 5 outputs + params = 11 x BLOCK x 4 B
+ 32 B; BLOCK=256 -> ~11.3 KiB, far below the ~16 MiB VMEM ceiling, leaving
room for double-buffering the HBM->VMEM pipeline on real hardware.

Pallas runs with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls; interpret mode lowers to plain HLO so the artifact is
executable by the rust runtime (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import (
    N_PARAMS,
    PARAM_ALPHA_MEM,
    PARAM_ALPHA_SYN_E,
    PARAM_ALPHA_SYN_I,
    PARAM_I_OFFSET,
    PARAM_T_REFRAC,
    PARAM_V_RESET,
    PARAM_V_REST,
    PARAM_V_THRESH,
)

# Default lane-block: a multiple of the 8x128 TPU vreg tile.
BLOCK = 256


def _lif_kernel(v_ref, ie_ref, ii_ref, rf_ref, xe_ref, xi_ref, p_ref,
                vo_ref, ieo_ref, iio_ref, rfo_ref, sp_ref):
    """Per-block body. All refs are VMEM-resident blocks."""
    p = p_ref[...]
    alpha_m = p[PARAM_ALPHA_MEM]
    alpha_e = p[PARAM_ALPHA_SYN_E]
    alpha_i = p[PARAM_ALPHA_SYN_I]
    v_rest = p[PARAM_V_REST]
    v_reset = p[PARAM_V_RESET]
    v_thresh = p[PARAM_V_THRESH]
    t_refrac = p[PARAM_T_REFRAC]
    i_offset = p[PARAM_I_OFFSET]

    i_exc = ie_ref[...] * alpha_e + xe_ref[...]
    i_inh = ii_ref[...] * alpha_i + xi_ref[...]

    total_i = i_exc - i_inh + i_offset
    v_free = v_rest + (v_ref[...] - v_rest) * alpha_m + total_i * (1.0 - alpha_m)

    refrac = rf_ref[...]
    in_refrac = refrac > 0.0
    v_clamped = jnp.where(in_refrac, v_reset, v_free)
    refrac_dec = jnp.maximum(refrac - 1.0, 0.0)

    spiked = jnp.logical_and(jnp.logical_not(in_refrac), v_clamped >= v_thresh)

    vo_ref[...] = jnp.where(spiked, v_reset, v_clamped)
    ieo_ref[...] = i_exc
    iio_ref[...] = i_inh
    rfo_ref[...] = jnp.where(spiked, t_refrac, refrac_dec)
    sp_ref[...] = spiked.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block",))
def lif_step(v, i_exc, i_inh, refrac, in_exc, in_inh, params, *, block=BLOCK):
    """One LIF timestep over ``n`` neurons (n must be a multiple of block,
    or smaller than block — the caller pads; the rust data generator always
    emits BLOCK-padded state vectors).

    Returns (v', i_exc', i_inh', refrac', spiked) — same contract as
    ``ref.lif_step_ref``.
    """
    n = v.shape[0]
    blk = min(block, n)
    assert n % blk == 0, f"n={n} not a multiple of block={blk}"
    grid = (n // blk,)
    state_spec = pl.BlockSpec((blk,), lambda i: (i,))
    # every grid step sees the whole params vector
    param_spec = pl.BlockSpec((N_PARAMS,), lambda i: (0,))
    out_shape = [jax.ShapeDtypeStruct((n,), jnp.float32)] * 5
    return tuple(
        pl.pallas_call(
            _lif_kernel,
            grid=grid,
            in_specs=[state_spec] * 6 + [param_spec],
            out_specs=[state_spec] * 5,
            out_shape=out_shape,
            interpret=True,
        )(v, i_exc, i_inh, refrac, in_exc, in_inh, params)
    )
