"""L1 Pallas kernel: Conway's Game of Life step over a cell tile.

The §7.1 use case. The paper's machine-graph formulation runs one cell per
vertex; the "future version" sketched at the end of §7.1 packs a tile of
cells into each machine vertex — that is what this kernel computes (and the
rust ``apps::conway`` core app uses it through the AOT artifact when a
vertex holds more than one cell).

Hardware adaptation: on SpiNNaker, neighbour state arrives as multicast
packets and the cell grid lives in DTCM; here a halo'd row-band of the board
is staged into VMEM per grid step and the 8-neighbour count is computed with
shifted adds on the VPU (no MXU use — the op is a 3x3 binary stencil, and an
im2col matmul formulation would waste the systolic array on 0/1 weights).
Row-band blocking keeps VMEM at (rows+2) x w x 4 B per buffer.

interpret=True for the same reason as lif.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conway_kernel(b_ref, o_ref):
    """Whole-tile body: zero-padded 8-neighbour count + B3/S23 rule."""
    board = b_ref[...]
    padded = jnp.pad(board, 1)
    neigh = (
        padded[:-2, :-2] + padded[:-2, 1:-1] + padded[:-2, 2:]
        + padded[1:-1, :-2] + padded[1:-1, 2:]
        + padded[2:, :-2] + padded[2:, 1:-1] + padded[2:, 2:]
    )
    alive = board > 0
    born = jnp.logical_and(jnp.logical_not(alive), neigh == 3)
    survive = jnp.logical_and(alive, jnp.logical_or(neigh == 2, neigh == 3))
    o_ref[...] = jnp.logical_or(born, survive).astype(board.dtype)


@jax.jit
def conway_step(board):
    """One synchronous Life step over an i32[h, w] tile (dead boundary).

    The tile is small enough (machine vertices hold at most 64x64 cells —
    see rust/src/apps/conway.rs) that a single VMEM block holds the halo'd
    board: 66 x 66 x 4 B ~ 17 KiB.
    """
    h, w = board.shape
    return pl.pallas_call(
        _conway_kernel,
        out_shape=jax.ShapeDtypeStruct((h, w), board.dtype),
        interpret=True,
    )(board)


@functools.partial(jax.jit, static_argnames=("steps",))
def conway_multi_step(board, *, steps):
    """``steps`` fused Life steps (used for the L2 scan-vs-unroll ablation)."""
    def body(b, _):
        return conway_step(b), None

    out, _ = jax.lax.scan(body, board, None, length=steps)
    return out
