"""Pure-jnp reference oracles for the L1 Pallas kernels.

These are the correctness ground truth: ``test_kernel.py`` asserts the
Pallas kernels (``lif.py``, ``conway.py``) match these to float tolerance
over hypothesis-driven shape/state sweeps.

The LIF model is the current-based exponential-synapse point neuron used by
the SpiNNaker neural front-end (sPyNNaker, Rhodes et al. 2018): exact
exponential decay of the membrane and both synaptic currents per 1 ms
timestep, Euler input mixing, threshold/reset with a refractory counter.
"""

import jax.numpy as jnp

# params vector layout (f32[8]) shared by ref, kernel, model and the rust
# data-generation code (see rust/src/apps/neuron.rs):
#   0: alpha_mem     exp(-dt/tau_m)
#   1: alpha_syn_e   exp(-dt/tau_syn_e)
#   2: alpha_syn_i   exp(-dt/tau_syn_i)
#   3: v_rest        mV
#   4: v_reset       mV
#   5: v_thresh      mV
#   6: t_refrac      refractory period in whole timesteps
#   7: i_offset      constant bias current (nA, scaled by R/tau factor)
PARAM_ALPHA_MEM = 0
PARAM_ALPHA_SYN_E = 1
PARAM_ALPHA_SYN_I = 2
PARAM_V_REST = 3
PARAM_V_RESET = 4
PARAM_V_THRESH = 5
PARAM_T_REFRAC = 6
PARAM_I_OFFSET = 7
N_PARAMS = 8


def lif_step_ref(v, i_exc, i_inh, refrac, in_exc, in_inh, params):
    """One 1 ms LIF timestep over a population slice.

    Args:
      v:       f32[n] membrane potentials (mV)
      i_exc:   f32[n] excitatory synaptic current state
      i_inh:   f32[n] inhibitory synaptic current state
      refrac:  f32[n] remaining refractory timesteps (>= 0)
      in_exc:  f32[n] excitatory input accumulated this step (weight sums)
      in_inh:  f32[n] inhibitory input accumulated this step
      params:  f32[8] see layout above

    Returns (v', i_exc', i_inh', refrac', spiked) with spiked in {0.0, 1.0}.
    """
    alpha_m = params[PARAM_ALPHA_MEM]
    alpha_e = params[PARAM_ALPHA_SYN_E]
    alpha_i = params[PARAM_ALPHA_SYN_I]
    v_rest = params[PARAM_V_REST]
    v_reset = params[PARAM_V_RESET]
    v_thresh = params[PARAM_V_THRESH]
    t_refrac = params[PARAM_T_REFRAC]
    i_offset = params[PARAM_I_OFFSET]

    # synaptic state: decay then add this step's arrivals
    i_exc_n = i_exc * alpha_e + in_exc
    i_inh_n = i_inh * alpha_i + in_inh

    # membrane: exact decay towards rest plus current injection
    total_i = i_exc_n - i_inh_n + i_offset
    v_free = v_rest + (v - v_rest) * alpha_m + total_i * (1.0 - alpha_m)

    # refractory clamp: hold at reset while counter > 0
    in_refrac = refrac > 0.0
    v_clamped = jnp.where(in_refrac, v_reset, v_free)
    refrac_dec = jnp.maximum(refrac - 1.0, 0.0)

    # threshold / reset
    spiked = jnp.logical_and(jnp.logical_not(in_refrac), v_clamped >= v_thresh)
    v_out = jnp.where(spiked, v_reset, v_clamped)
    refrac_out = jnp.where(spiked, t_refrac, refrac_dec)

    return v_out, i_exc_n, i_inh_n, refrac_out, spiked.astype(jnp.float32)


def conway_step_ref(board):
    """One synchronous Conway step over an i32[h, w] board of {0, 1}.

    Cells beyond the board edge are dead (zero padding) — matching the
    per-vertex machine-graph formulation of §7.1, where a missing neighbour
    simply never sends a state packet.
    """
    padded = jnp.pad(board, 1)
    neigh = (
        padded[:-2, :-2] + padded[:-2, 1:-1] + padded[:-2, 2:]
        + padded[1:-1, :-2] + padded[1:-1, 2:]
        + padded[2:, :-2] + padded[2:, 1:-1] + padded[2:, 2:]
    )
    alive = board > 0
    born = jnp.logical_and(jnp.logical_not(alive), neigh == 3)
    survive = jnp.logical_and(alive, jnp.logical_or(neigh == 2, neigh == 3))
    return jnp.logical_or(born, survive).astype(board.dtype)
